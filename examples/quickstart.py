"""Quickstart: HC-SMoE in ~40 lines.

Builds a small Mixtral-family MoE, runs the paper's full pipeline —
calibrate -> hierarchically cluster expert outputs -> frequency-merge ->
group-map routing — and compares the merged model against the original.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import HCSMoEConfig, run_hcsmoe
from repro.core.quality import output_fidelity
from repro.data import calibration_batches
from repro.models import build_model

# 1. a small Mixtral-family SMoE (8 experts, top-2) — swap in any of the 12
#    registry configs ("deepseek-v2-236b", "qwen1.5-moe-a2.7b", ...) at full
#    scale on a real cluster; .reduced() keeps this runnable on a laptop CPU.
cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"model: {cfg.name}  experts/layer: {cfg.moe.num_experts}  "
      f"params: {cfg.param_counts()[0] / 1e6:.2f}M (analytic, full tree)")

# 2. calibration set (the paper uses 32 x 2048-token C4 sequences)
calib = calibration_batches(cfg, n_seqs=8, seq_len=128, batch=4)

# 3. HC-SMoE: expert-output metric, average-linkage HC, frequency merging
hc = HCSMoEConfig(target_experts=4, linkage="average",
                  metric="expert_output", merge="frequency")
merged_params, info = run_hcsmoe(model, params, calib, hc)
labels = info["layers"][0]["labels"]
print(f"layer-0 clusters (8 -> 4): {labels.tolist()}")

# 4. the router is untouched; merged slots are reached via group_map
gm = merged_params["decoder"]["blocks"]["layer0"]["moe"]["group_map"]
print(f"group_map: {jnp.asarray(gm)[0].tolist()}")

# 5. compare outputs (task-agnostic fidelity, paper Table 23 metrics)
fid = output_fidelity(model, params, merged_params, calib[:1],
                      moe_mode="dense")
print(f"merged-vs-original logits: L2={fid['l2_error']:.2f}  "
      f"cosine={fid['cosine_similarity']:.4f}")

# 6. generate with both (greedy)
toks = jnp.asarray([[5, 17, 42, 7]])
for name, p in [("original", params), ("merged", merged_params)]:
    lp, cache = model.prefill(p, tokens=toks, cache_max_len=16)
    out = [int(jnp.argmax(lp[0, -1]))]
    for _ in range(5):
        ld, cache = model.decode_step(p, tokens=jnp.asarray([[out[-1]]]),
                                      cache=cache)
        out.append(int(jnp.argmax(ld[0, -1])))
    print(f"{name:9s} generates: {out}")
