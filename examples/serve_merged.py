"""Serving example: batched continuous-batching inference with an HC-SMoE
compressed model, comparing weight memory and throughput against the
original — the paper's deployment scenario (Table 20).

  PYTHONPATH=src python examples/serve_merged.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import HCSMoEConfig, run_hcsmoe
from repro.data import calibration_batches
from repro.models import build_model
from repro.serving import Request, ServingEngine


def param_bytes(params):
    import jax.numpy as jnp

    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating))


def main():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    calib = calibration_batches(cfg, n_seqs=8, seq_len=64, batch=4)
    merged, _ = run_hcsmoe(model, params, calib,
                           HCSMoEConfig(target_experts=4))

    print(f"weights: original {param_bytes(params)/2**20:.1f} MiB -> "
          f"merged {param_bytes(merged)/2**20:.1f} MiB")

    rng = np.random.RandomState(0)
    for name, p in [("original", params), ("HC-SMoE merged", merged)]:
        engine = ServingEngine(model, p, batch_slots=4, max_len=64,
                               moe_mode="ragged")
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=12) for i in range(8)]
        for r in reqs:
            engine.submit(r)
        engine.step()  # pay compile cost before timing
        t0 = time.time()
        engine.run()
        dt = time.time() - t0
        toks = sum(len(r.generated) for r in reqs)
        print(f"{name:16s}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s, batch_slots=4)")
        print(f"  sample: {reqs[0].generated}")


if __name__ == "__main__":
    main()
