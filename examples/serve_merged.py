"""Serving example: continuous-batching inference with an HC-SMoE compressed
model, comparing weight memory, throughput, and time-to-first-token against
the original — the paper's deployment scenario (Table 20).

  PYTHONPATH=src python examples/serve_merged.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import HCSMoEConfig, run_hcsmoe
from repro.data import calibration_batches
from repro.models import build_model
from repro.serving import Request, ServingConfig, ServingEngine


def param_bytes(params):
    import jax.numpy as jnp

    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating))


def main():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    calib = calibration_batches(cfg, n_seqs=8, seq_len=64, batch=4)
    merged, _ = run_hcsmoe(model, params, calib,
                           HCSMoEConfig(target_experts=4))

    print(f"weights: original {param_bytes(params)/2**20:.1f} MiB -> "
          f"merged {param_bytes(merged)/2**20:.1f} MiB")

    rng = np.random.RandomState(0)
    for name, p in [("original", params), ("HC-SMoE merged", merged)]:
        engine = ServingEngine(model, p, config=ServingConfig(
            batch_slots=4, max_len=64, moe_mode="ragged"))
        # mixed prompt lengths: bucketing keeps this to ~2 compiled prefills
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab_size,
                                           int(n)).astype(np.int32),
                        max_new_tokens=12)
                for i, n in enumerate([5, 8, 11, 16, 6, 9, 13, 7])]
        # warm-up with an identical workload so every prefill bucket the
        # timed window needs is compiled before timing starts
        for r in reqs:
            engine.submit(Request(uid=100 + r.uid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens))
        engine.run()
        engine.reset_stats()
        for r in reqs:
            engine.submit(r)
        engine.run()
        st = engine.stats()
        print(f"{name:16s}: {st.total_new_tokens} tokens in "
              f"{st.wall_time_s:.2f}s ({st.tokens_per_s:.1f} tok/s, "
              f"mean TTFT {st.mean_ttft_s * 1e3:.0f} ms, "
              f"{st.prefill_compilations} compiled prefill shapes)")
        print(f"  sample: {reqs[0].generated}")


if __name__ == "__main__":
    main()
