"""Model-zoo tour: run one forward + one decode step for EVERY assigned
architecture (reduced configs) — dense, MoE, MLA, hybrid Mamba, xLSTM,
encoder-decoder, and VLM — through the same Model API.

  PYTHONPATH=src python examples/multiarch_smoke.py
"""
import time

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model


def main():
    key = jax.random.PRNGKey(0)
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch).reduced(dtype="float32")
        model = build_model(cfg)
        t0 = time.time()
        params = model.init(key)
        B, S = 2, 16
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        kwargs = {"tokens": toks}
        if cfg.family == "encdec":
            kwargs["src_frames"] = jax.random.normal(key, (B, S, cfg.d_model))
        if cfg.family == "vlm":
            kwargs["patch_embeds"] = jax.random.normal(
                key, (B, cfg.num_patch_tokens, cfg.d_model))
        logits, _ = model.forward(params, **kwargs, moe_mode="dense")
        lp, cache = model.prefill(params, **kwargs, cache_max_len=32,
                                  moe_mode="dense")
        ld, cache = model.decode_step(params, tokens=toks[:, -1:], cache=cache,
                                      moe_mode="dense")
        total, active = cfg.param_counts()
        print(f"{arch:24s} [{cfg.family:6s}] full-scale params "
              f"{total/1e9:7.2f}B (active {active/1e9:6.2f}B)  "
              f"smoke fwd+decode ok ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
