"""End-to-end driver: TRAIN a ~small MoE LM for a few hundred steps on the
domain-structured synthetic stream (with checkpoint/resume), then run the
full HC-SMoE comparison — original vs merged vs the paper's baselines —
on held-out evaluation tasks.

  PYTHONPATH=src python examples/train_merge_eval.py [--steps 400]

This is the e2e training deliverable: it exercises the fault-tolerant
trainer (checkpointing + exact resume), the calibration pass, every
compression baseline, and the evaluation harness.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import HCSMoEConfig, apply_hcsmoe, collect_moe_stats
from repro.core import baselines as bl
from repro.core.quality import eval_loss
from repro.data import TokenStream
from repro.models import build_model
from repro.parallel import ParallelConfig
from repro.training import OptimizerConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--experts", type=int, default=12)
    args = ap.parse_args()

    import dataclasses

    base = get_config("qwen1.5-moe-a2.7b").reduced(dtype="float32")
    cfg = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, num_experts=args.experts,
                                      top_k=2))
    model = build_model(cfg)

    # ---- train with checkpointing ------------------------------------
    stream = TokenStream(cfg.vocab_size, seq_len=32, global_batch=8, seed=0,
                         n_domains=8)
    ckpt_dir = tempfile.mkdtemp(prefix="hcsmoe_example_")
    oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=10,
                         total_steps=args.steps, weight_decay=0.0)
    tc = TrainConfig(total_steps=args.steps, ckpt_every=max(50, args.steps // 4),
                     ckpt_dir=ckpt_dir, log_every=max(10, args.steps // 10))
    pc = ParallelConfig(remat="none", moe_mode="dense")
    params, _, log = train(model, stream, oc, tc, pc)
    print("training curve:",
          " ".join(f"{e['step']}:{e['loss']:.3f}" for e in log))

    # ---- calibrate ----------------------------------------------------
    calib = [{"tokens": jnp.asarray(stream.batch(10_000 + i)["tokens"])}
             for i in range(3)]
    stats = collect_moe_stats(model, params, calib)

    # ---- eval protocol: held-out batches ------------------------------
    evalb = [jax.tree.map(jnp.asarray, stream.batch(50_000 + i))
             for i in range(4)]

    def score(p):
        return eval_loss(model, p, evalb, moe_mode="dense")

    E = cfg.moe.num_experts
    r = E // 2
    print(f"\n=== {E} -> {r} experts/layer (50% reduction) ===")
    print(f"{'original':22s} {score(params):.4f}")
    merged, _ = apply_hcsmoe(cfg, params, stats, HCSMoEConfig(target_experts=r))
    print(f"{'HC-SMoE (avg, eo)':22s} {score(merged):.4f}")
    for name, fn in [
        ("M-SMoE", lambda: bl.m_smoe(cfg, params, stats, r)[0]),
        ("F-prune", lambda: bl.f_prune(cfg, params, stats, r)[0]),
        ("S-prune", lambda: bl.s_prune(cfg, params, stats, r)[0]),
        ("O-prune (sampled)", lambda: bl.o_prune(cfg, params, stats, r,
                                                 samples=16)[0]),
    ]:
        print(f"{name:22s} {score(fn()):.4f}")


if __name__ == "__main__":
    main()
