"""Speculative decoding: the MergePlan-derived draft must be LOSSLESS.

Every stream a speculative engine emits must be bit-identical to the same
request served without speculation — greedy AND seeded stochastic — across
attention backends, prefix caching, EP, and forced mid-speculation
preemption. The draft model only moves the acceptance rate, never the
output (repro.serving.speculative module docstring)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import HCSMoEConfig, collect_moe_stats, compute_plan
from repro.models import build_model
from repro.serving import (
    Request, SamplingParams, ServingConfig, ServingEngine, SpecConfig)
from repro.serving.faults import FaultConfig


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def draft_plan(served):
    """Aggressive 2-expert plan: a cheap draft with a real (imperfect)
    acceptance rate against the unmerged target."""
    cfg, model, params = served
    key = jax.random.PRNGKey(3)
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                           (2, 32), 0, cfg.vocab_size)}
             for i in range(2)]
    stats = collect_moe_stats(model, params, calib)
    return compute_plan(cfg, params, stats, HCSMoEConfig(target_experts=2))


def _requests(cfg, *, shared_prefix=0, n=3, max_new=10):
    """Mixed-sampler request set: greedy plus two distinct seeded
    stochastic streams, so parity covers both acceptance-rule branches."""
    rng = np.random.RandomState(9)
    prefix = rng.randint(0, cfg.vocab_size, shared_prefix).astype(np.int32)
    samplings = [SamplingParams(),
                 SamplingParams(temperature=0.8, top_p=0.9, seed=7),
                 SamplingParams(temperature=1.2, seed=11)]
    reqs = []
    for i in range(n):
        tail = rng.randint(0, cfg.vocab_size, 3 + 2 * i).astype(np.int32)
        reqs.append(Request(uid=i,
                            prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=max_new,
                            sampling=samplings[i % len(samplings)]))
    return reqs


def _serve(model, params, cfg, *, spec, shared_prefix=0, n=3, max_new=10,
           **cfg_kw):
    eng = ServingEngine(model, params, config=ServingConfig(
        batch_slots=3, max_len=64, kv_layout="paged", speculative=spec,
        **cfg_kw))
    reqs = _requests(cfg, shared_prefix=shared_prefix, n=n, max_new=max_new)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.generated) for r in reqs], eng.stats()


# ---------------------------------------------------------------------------
# Lossless verification
# ---------------------------------------------------------------------------


def test_lossless_across_backends_and_prefix_cache(served, draft_plan):
    """ONE non-speculative reference; every {jnp,pallas} x {prefix
    cache on,off} speculative combo must reproduce it token-for-token
    (backend parity of the non-spec engine is already pinned by
    tests/test_serving.py, so a single reference suffices)."""
    cfg, model, params = served
    reference, _ = _serve(model, params, cfg, spec=None, shared_prefix=16)

    for impl in ("jnp", "pallas"):
        for prefix in (False, True):
            toks, st = _serve(
                model, params, cfg,
                spec=SpecConfig(draft_plan=draft_plan, k=3),
                shared_prefix=16, attn_impl=impl, prefix_cache=prefix)
            assert toks == reference, \
                f"{impl}/prefix={prefix} diverged from non-speculative run"
            assert st.spec_rounds > 0
            assert st.draft_tokens > 0
            assert 0.0 <= st.acceptance_rate <= 1.0
            assert st.spec_tokens_per_round >= 1.0
            assert st.draft_time_s >= 0.0


def test_lossless_under_expert_parallel_mesh(served, draft_plan):
    """Speculative verify reuses the EP extend dispatch: paged + EP +
    speculation must match the single-device non-speculative stream.
    (Single-process 1-device mesh; the 8-device case rides in
    tests/test_multidevice.py's matrix.)"""
    from repro.launch.mesh import make_serving_mesh
    from repro.parallel import ParallelConfig

    cfg, model, params = served
    reference, _ = _serve(model, params, cfg, spec=None)
    toks, st = _serve(
        model, params, cfg, spec=SpecConfig(draft_plan=draft_plan, k=3),
        parallel=ParallelConfig(fsdp_axis=None, weight_gather=False,
                                ep=True),
        mesh=make_serving_mesh())
    assert toks == reference
    assert st.spec_rounds > 0


def test_lossless_under_forced_preemption_mid_speculation(served,
                                                          draft_plan):
    """Chaos preemption every 2 steps lands inside speculative rounds;
    preempted slots lose their draft sync state, lazily re-prefill the
    draft cache on re-admission, and the streams still match an
    unpreempted non-speculative run exactly."""
    cfg, model, params = served
    reference, _ = _serve(model, params, cfg, spec=None, n=4)
    toks, st = _serve(
        model, params, cfg, spec=SpecConfig(draft_plan=draft_plan, k=3),
        n=4, faults=FaultConfig(preempt_every=2))
    assert st.preemptions > 0, "fault injection never fired"
    assert toks == reference


def test_self_draft_accepts_everything(served, draft_plan):
    """merge_plan == draft_plan makes draft and target the same model, so
    the seeded-equality rule accepts every budgeted draft: acceptance
    rate 1.0 and ~k+1 tokens per stream per verify."""
    cfg, model, params = served
    toks, st = _serve(
        model, params, cfg,
        spec=SpecConfig(draft_plan=draft_plan, k=3),
        merge_plan=draft_plan)
    assert st.draft_tokens > 0
    assert st.acceptance_rate == pytest.approx(1.0)
    # full acceptance => every round emits budget+1 per stream; with
    # max_new=10, k=3 that is >= 2.5 tokens/stream/verify even after
    # tail-of-stream budget clipping
    assert st.spec_tokens_per_round >= 2.5
    # speculation replaces per-token dispatch: far fewer target decode
    # dispatches than emitted tokens
    emitted = sum(len(t) for t in toks)
    assert st.spec_rounds < emitted


# ---------------------------------------------------------------------------
# Config surface
# ---------------------------------------------------------------------------


class TestSpecConfigValidation:
    def test_draft_plan_required(self):
        with pytest.raises(ValueError, match="draft_plan"):
            SpecConfig().validate()

    def test_k_positive(self, draft_plan):
        with pytest.raises(ValueError, match="k"):
            SpecConfig(draft_plan=draft_plan, k=0).validate()

    def test_requires_paged_layout(self, draft_plan):
        with pytest.raises(ValueError, match="paged"):
            ServingConfig(kv_layout="contiguous",
                          speculative=SpecConfig(
                              draft_plan=draft_plan)).validate()

    def test_rejects_non_specconfig(self):
        with pytest.raises(ValueError, match="SpecConfig"):
            ServingConfig(kv_layout="paged", speculative=42).validate()
