"""Flash-decode kernel vs the pure-jnp oracle, across ring-buffer
wrap-around, sliding windows, logit softcap, GQA ratios, unfilled-slot
sentinels, and dtypes — all in interpret mode on CPU — plus the engine-level
attn_impl switch: greedy serving must be token-identical across backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import build_model


def _tol(dtype):
    # acceptance: <= 1e-3 (f32) / <= 2e-2 (bf16) vs the oracle
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-3, atol=1e-3)


def _ring_kv_pos(W, pos_vals):
    """The engine's ring-buffer invariant: slot w holds the newest absolute
    position p <= pos with p % W == w, or -1 if no such p exists yet."""
    kv_pos = np.full((len(pos_vals), W), -1, np.int32)
    for b, p in enumerate(pos_vals):
        for w in range(W):
            if p >= w:
                kv_pos[b, w] = w + ((p - w) // W) * W
    return jnp.asarray(kv_pos)


def _case(B, W, K, G, hd, pos_vals, dtype=jnp.float32, seed=0):
    H = K * G
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, W, K, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, W, K, hd), dtype)
    pos = jnp.asarray(np.asarray(pos_vals, np.int32))
    return q, k, v, _ring_kv_pos(W, pos_vals), pos


def _check(q, k, v, kv_pos, pos, **kw):
    o = ops.flash_decode(q, k, v, kv_pos, pos, **kw)
    o_ref = ref.flash_decode_ref(q, k, v, kv_pos, pos, **kw)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(q.dtype))


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G", [1, 4, 8])  # GQA ratios H/K
def test_gqa_ratios(G, dtype):
    _check(*_case(2, 64, 2, G, 32, [5, 63], dtype=dtype))


@pytest.mark.parametrize("pos_vals", [[64], [100], [257]])
def test_ring_buffer_wraparound(pos_vals):
    """pos > W: every slot is overwritten at least once; kv_pos holds the
    newest generation and the causal mask must still be exact."""
    _check(*_case(1, 64, 2, 4, 32, pos_vals))


def test_ring_buffer_wraparound_multitile():
    """W > 128 splits into several KV tiles (W <= 128 runs as one); wrap
    must be exact across tile boundaries too."""
    _check(*_case(2, 256, 2, 2, 16, [300, 511]))


def test_partial_fill_tile_skipping():
    """Slots past pos+1 are unfilled (-1); whole tiles beyond each slot's
    filled prefix are skipped via the scalar-prefetched pos (W=256 -> two
    128-row tiles; pos <= 9 leaves tile 1 entirely skippable) and must
    contribute nothing."""
    _check(*_case(3, 256, 2, 4, 32, [0, 3, 9]))


def test_mixed_lengths_in_batch():
    """Per-slot lengths differ wildly — each row's skip boundary is its
    own (multi-tile: rows 0/1 use only tile 0, rows 2/3 all three)."""
    _check(*_case(4, 384, 2, 2, 16, [1, 40, 300, 500]))


@pytest.mark.parametrize("window", [8, 16])
def test_sliding_window(window):
    """Local layers: only the last `window` positions attend, including
    post-wrap where the window straddles the ring seam."""
    _check(*_case(2, 48, 1, 4, 16, [7, 200]), window=window)


def test_logit_softcap():
    _check(*_case(2, 64, 2, 4, 32, [30, 63]), logit_cap=30.0)


def test_softcap_and_window_fused():
    """gemma2-style local layer: softcap AND sliding window in one kernel."""
    _check(*_case(2, 32, 2, 2, 16, [10, 100]), window=16, logit_cap=50.0)


def test_unfilled_sentinel_holes():
    """Arbitrary kv_pos = -1 holes (not just a contiguous tail) must be
    masked — robustness beyond the engine's dense-prefix invariant."""
    q, k, v, kv_pos, pos = _case(2, 64, 2, 4, 32, [63, 63])
    holes = np.asarray(kv_pos).copy()
    holes[0, 5:20] = -1
    holes[1, ::3] = -1
    _check(q, k, v, jnp.asarray(holes), pos)


def test_custom_scale():
    _check(*_case(1, 32, 2, 2, 16, [31]), scale=0.25)


def test_oracle_matches_jnp_decode_path():
    """The standalone oracle and the model's jnp decode mask/softmax agree
    (same filled/causal/window semantics, softcap before masking)."""
    from repro.models.attention import _attend, make_mask_fn

    q, k, v, kv_pos, pos = _case(2, 48, 2, 4, 16, [11, 90])
    for kind, window, cap in (("causal", 0, 0.0), ("local", 16, 30.0)):
        mask = make_mask_fn(kind, window)(pos[:, None], kv_pos)
        o_jnp = _attend(q[:, None], k, v, mask, 0.25, cap)[:, 0]
        o_ref = ref.flash_decode_ref(q, k, v, kv_pos, pos, scale=0.25,
                                     window=window if kind == "local" else 0,
                                     logit_cap=cap)
        np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# GQA prefill flash attention (bucketed-prefill path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kv", [(8, 2), (4, 4), (8, 1)])
def test_prefill_flash_gqa(h, kv):
    key = jax.random.PRNGKey(h)
    q = jax.random.normal(key, (2, 128, h, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, kv, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, kv, 32))
    o = ops.flash_attention(q, k, v, causal=True)
    o_ref = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("window,cap", [(16, 0.0), (16, 50.0), (8, 30.0),
                                        (1, 0.0)])
def test_prefill_flash_windowed_softcap(window, cap):
    """Sliding-window + softcap fused into the prefill kernel (gemma2-style
    local layers). window=1 is the degenerate diagonal-only band; every
    windowed row's FIRST live KV tile can be fully masked, so this also
    guards the masked-prob zeroing in the online softmax."""
    key = jax.random.PRNGKey(window)
    q = jax.random.normal(key, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            logit_cap=cap)
    o_ref = ref.attention_ref(q, k, v, causal=True, window=window,
                              logit_cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)


def test_prefill_flash_windowed_multitile():
    """window smaller than a KV tile AND spanning tile boundaries: S=256
    -> two 128-row tiles; window=40 straddles the tile-0/tile-1 seam for
    rows 128..167, and the clamped index map must still fetch the right
    lo/hi tile band."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 256, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 32))
    o = ops.flash_attention(q, k, v, causal=True, window=40, logit_cap=30.0)
    o_ref = ref.attention_ref(q, k, v, causal=True, window=40,
                              logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)


def test_prefill_windowed_parity_vs_attention_forward():
    """gemma2-style config: attn_impl='pallas' prefill now routes local
    sliding-window + softcap layers through the fused flash kernel instead
    of falling back to jnp — logits must match attention_forward exactly
    (the satellite parity requirement)."""
    cfg = get_config("gemma2-2b").reduced(dtype="float32")
    assert cfg.sliding_window and cfg.attn_logit_softcap
    model_j = build_model(cfg)
    model_p = build_model(dataclasses.replace(cfg, attn_impl="pallas"))
    params = model_j.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0,
                              cfg.vocab_size)
    lj, cache_j = model_j.prefill(params, tokens=toks, cache_max_len=32)
    lp, cache_p = model_p.prefill(params, tokens=toks, cache_max_len=32)
    np.testing.assert_allclose(np.asarray(lj), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree.leaves(cache_j), jax.tree.leaves(cache_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_prefill_parity_vs_attention_forward():
    """cfg.attn_impl='pallas' prefill must match the jnp attention_forward
    on the same params/tokens (the satellite parity requirement)."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model_j = build_model(cfg)
    model_p = build_model(dataclasses.replace(cfg, attn_impl="pallas"))
    params = model_j.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    lj, cache_j = model_j.prefill(params, tokens=toks, cache_max_len=32)
    lp, cache_p = model_p.prefill(params, tokens=toks, cache_max_len=32)
    np.testing.assert_allclose(np.asarray(lj), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)
    # the caches the two backends hand to decode are identical too
    for a, b in zip(jax.tree.leaves(cache_j), jax.tree.leaves(cache_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Model-level decode parity (full stack, ring cache, multiple archs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "gemma2-2b"])
def test_decode_stack_parity(arch):
    """Full prefill+decode through both backends: gemma2 exercises the
    local sliding-window + softcap kernel path, mixtral plain causal GQA."""
    cfg = get_config(arch).reduced(dtype="float32")
    model_j = build_model(cfg)
    model_p = build_model(dataclasses.replace(cfg, attn_impl="pallas"))
    params = model_j.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    lj, cj = model_j.prefill(params, tokens=toks, cache_max_len=24)
    lp, cp = model_p.prefill(params, tokens=toks, cache_max_len=24)
    nxt = jnp.argmax(lj[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(6):
        dj, cj = model_j.decode_step(params, tokens=nxt, cache=cj)
        dp, cp = model_p.decode_step(params, tokens=nxt, cache=cp)
        np.testing.assert_allclose(np.asarray(dj), np.asarray(dp),
                                   rtol=1e-4, atol=1e-4)
        nxt = jnp.argmax(dj[:, 0], -1)[:, None].astype(jnp.int32)
