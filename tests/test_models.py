"""Per-arch smoke tests (assignment requirement f): reduced same-family
configs, one forward + one train step on CPU, asserting shapes + finiteness,
plus decode-parity integration tests across every mixer type."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model

ARCHS = list(ALL_ARCHS)


def _batch_for(cfg, key, B=2, S=24):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["src_frames"] = jax.random.normal(
            key, (B, S, cfg.d_model)).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patch_tokens, cfg.d_model)).astype(
                jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch_for(cfg, key)

    logits, aux = model.forward(
        params, **{k: v for k, v in batch.items() if k != "labels"},
        moe_mode="dense")
    # logits come back over the PADDED vocab (multiple of 256) with the
    # padding ids masked to -inf-like values; slice to the live region
    assert logits.shape[-1] == cfg.padded_vocab_size
    logits = logits[..., :cfg.vocab_size]
    S_text = batch["tokens"].shape[1]
    expect_S = S_text + (cfg.num_patch_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"

    # one real optimizer step
    from repro.training import OptimizerConfig, apply_updates, init_opt_state

    loss, metrics = model.train_loss(params, batch, moe_mode="dense",
                                     remat="none")
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.train_loss(p, batch, moe_mode="dense",
                                                remat="none")[0],
                     allow_int=True)(params)
    new_params, _, om = apply_updates(params, grads, init_opt_state(params),
                                      OptimizerConfig(peak_lr=1e-3))
    assert np.isfinite(float(om["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params))
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating))
    assert moved


@pytest.mark.parametrize("arch", [
    "llama3.2-1b", "gemma2-2b", "mixtral-8x7b", "deepseek-v2-236b",
    "jamba-v0.1-52b", "xlstm-125m", "moonshot-v1-16b-a3b", "granite-3-2b",
])
def test_decode_matches_forward(arch, key):
    """prefill + token-by-token decode == full parallel forward."""
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, tokens=toks, moe_mode="dense")
    p = S - 4
    lp, cache = model.prefill(params, tokens=toks[:, :p], cache_max_len=S,
                              moe_mode="dense")
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, p - 1]),
                               rtol=2e-4, atol=2e-4)
    for i in range(p, S):
        ld, cache = model.decode_step(params, tokens=toks[:, i:i + 1],
                                      cache=cache, moe_mode="dense")
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_encdec_decode_matches_forward(key):
    cfg = get_config("seamless-m4t-large-v2").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    B, S, Ssrc = 2, 16, 20
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    src = jax.random.normal(key, (B, Ssrc, cfg.d_model))
    full, _ = model.forward(params, tokens=toks, src_frames=src,
                            moe_mode="dense")
    p = S - 3
    lp, cache = model.prefill(params, tokens=toks[:, :p], src_frames=src,
                              cache_max_len=max(S, Ssrc), moe_mode="dense")
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, p - 1]),
                               rtol=2e-4, atol=2e-4)
    for i in range(p, S):
        ld, cache = model.decode_step(params, tokens=toks[:, i:i + 1],
                                      cache=cache, moe_mode="dense")
        np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_buffer(key):
    """gemma2 local layers: decoding far past the window must only attend to
    the last `window` tokens — equivalence with a model fed only the tail is
    NOT exact (global layers differ), so instead check ring-buffer caches stay
    finite and the kv_pos window invariant holds."""
    cfg = get_config("gemma2-2b").reduced(dtype="float32", sliding_window=8)
    model = build_model(cfg)
    params = model.init(key)
    B, S = 1, 20
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    lp, cache = model.prefill(params, tokens=toks[:, :4], cache_max_len=32)
    for i in range(4, S):
        ld, cache = model.decode_step(params, tokens=toks[:, i:i + 1],
                                      cache=cache)
        assert bool(jnp.isfinite(ld).all())
    # local layer (pattern pos 0) cache is ring of size 8
    local_cache = cache["blocks"][0]
    assert local_cache["k"].shape[2] == 8
    kvp = np.asarray(local_cache["kv_pos"])[:, 0]  # block 0
    live = kvp[kvp >= 0]
    assert live.max() == S - 1 and live.min() >= S - 8


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v2-236b"])
def test_moe_paths_agree(arch, key):
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    l_dense, _ = model.forward(params, tokens=toks, moe_mode="dense")
    l_ragged, _ = model.forward(params, tokens=toks, moe_mode="ragged")
    l_pallas, _ = model.forward(params, tokens=toks, moe_mode="pallas")
    np.testing.assert_allclose(np.asarray(l_dense), np.asarray(l_ragged),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(l_dense), np.asarray(l_pallas),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_match_actual():
    """Analytic param_counts (used for MODEL_FLOPS) vs real init, per family
    representative. Allow small deviation (norm deltas etc.)."""
    for arch in ["llama3.2-1b", "mixtral-8x7b"]:
        cfg = get_config(arch)
        model = build_model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(sds)
                     if jnp.issubdtype(l.dtype, jnp.floating))
        analytic, _ = cfg.param_counts()
        assert abs(actual - analytic) / analytic < 0.02, (arch, actual, analytic)
