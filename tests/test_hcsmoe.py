"""Integration + property tests for the full HC-SMoE pipeline (Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import HCSMoEConfig, apply_hcsmoe, collect_moe_stats
from repro.core import baselines as bl
from repro.core.calibration import flatten_stats
from repro.core.quality import cluster_quality_report, eval_loss, output_fidelity
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    batches = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                             (2, 64), 0, cfg.vocab_size)}
               for i in range(3)]
    stats = collect_moe_stats(model, params, batches)
    return cfg, model, params, batches, stats


def test_stats_shapes(setup):
    cfg, model, params, batches, stats = setup
    layers = flatten_stats(cfg, stats)
    assert len(layers) == cfg.num_layers
    st = layers[0]["stats"]
    E = cfg.moe.num_experts
    assert st.out_sum.shape == (E, cfg.d_model)
    assert float(st.token_count) == sum(
        b["tokens"].size for b in batches)
    assert st.freq.shape == (E,)
    # every token picks top_k experts
    np.testing.assert_allclose(float(st.freq.sum()),
                               float(st.token_count) * cfg.moe.top_k)


def test_capture_stats_rejects_merged_params(setup):
    """Calibration stats are pre-merge-only: freq/logits are indexed by the
    ORIGINAL expert ids, so capturing stats over merged slot weights would
    produce a shape- (resized) or semantics- (padded) inconsistent MoEStats.
    Both merged representations must be refused."""
    cfg, model, params, batches, stats = setup
    from repro.models.moe import moe_forward

    merged, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=4))
    with pytest.raises(ValueError, match="merged|pre-merge|original"):
        collect_moe_stats(model, merged, batches[:1])

    # resize=False keeps E padded slots — only the group_map betrays the
    # merge; the value-level preflight must still catch it
    padded, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=4, resize=False))
    with pytest.raises(ValueError, match="merged|pre-merge|original"):
        collect_moe_stats(model, padded, batches[:1])

    # layer-level: merged slot count != cfg.moe.num_experts raises at trace
    moe_p = jax.tree.map(lambda x: x[0],
                         merged["decoder"]["blocks"]["layer0"]["moe"])
    x = jnp.zeros((1, 8, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match="pre-merge"):
        moe_forward(moe_p, cfg, x, mode="dense", capture_stats=True)


def test_merge_to_r_equals_e_is_exact_identity(setup):
    """r == E: every expert its own cluster -> merged model must be
    bit-identical in function to the original (key invariant)."""
    cfg, model, params, batches, stats = setup
    E = cfg.moe.num_experts
    merged, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=E))
    toks = batches[0]["tokens"]
    a, _ = model.forward(params, tokens=toks, moe_mode="dense")
    b, _ = model.forward(merged, tokens=toks, moe_mode="dense")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_merged_model_all_paths_consistent(setup):
    cfg, model, params, batches, stats = setup
    merged, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=4))
    toks = batches[0]["tokens"]
    a, _ = model.forward(merged, tokens=toks, moe_mode="dense")
    b, _ = model.forward(merged, tokens=toks, moe_mode="ragged")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_group_map_is_valid_surjection(setup):
    cfg, model, params, batches, stats = setup
    r = 3
    merged, info = apply_hcsmoe(cfg, params, stats,
                                HCSMoEConfig(target_experts=r))
    gm = np.asarray(
        merged["decoder"]["blocks"]["layer0"]["moe"]["group_map"])
    assert gm.shape == (cfg.num_blocks, cfg.moe.num_experts)
    for row in gm:
        assert set(row) == set(range(r))  # surjective onto merged slots


def test_merged_weight_shapes_resized(setup):
    cfg, model, params, batches, stats = setup
    merged, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=4))
    moe = merged["decoder"]["blocks"]["layer0"]["moe"]
    assert moe["wg"].shape[1] == 4
    assert moe["router"].shape[-1] == cfg.moe.num_experts  # router untouched


def test_router_untouched(setup):
    cfg, model, params, batches, stats = setup
    merged, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=4))
    np.testing.assert_array_equal(
        np.asarray(params["decoder"]["blocks"]["layer0"]["moe"]["router"]),
        np.asarray(merged["decoder"]["blocks"]["layer0"]["moe"]["router"]))


def test_determinism_end_to_end(setup):
    cfg, model, params, batches, stats = setup
    m1, _ = apply_hcsmoe(cfg, params, stats, HCSMoEConfig(target_experts=4))
    m2, _ = apply_hcsmoe(cfg, params, stats, HCSMoEConfig(target_experts=4))
    for a, b in zip(jax.tree_util.tree_leaves(m1),
                    jax.tree_util.tree_leaves(m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("variant", [
    HCSMoEConfig(target_experts=4, linkage="single"),
    HCSMoEConfig(target_experts=4, linkage="complete"),
    HCSMoEConfig(target_experts=4, metric="router_logits"),
    HCSMoEConfig(target_experts=4, metric="weight"),
    HCSMoEConfig(target_experts=4, merge="average"),
    HCSMoEConfig(target_experts=4, merge="fix_dom"),
    HCSMoEConfig(target_experts=4, clustering="kmeans_fix"),
    HCSMoEConfig(target_experts=4, clustering="kmeans_rnd"),
    HCSMoEConfig(target_experts=4, clustering="fcm", resize=False),
    HCSMoEConfig(target_experts=4, non_uniform=True, resize=False),
])
def test_all_variants_produce_working_models(setup, variant):
    cfg, model, params, batches, stats = setup
    merged, _ = apply_hcsmoe(cfg, params, stats, variant)
    logits, _ = model.forward(merged, tokens=batches[0]["tokens"],
                              moe_mode="dense")
    assert bool(jnp.isfinite(logits).all())


def test_baselines_produce_working_models(setup):
    cfg, model, params, batches, stats = setup
    eb = [{**b, "labels": b["tokens"]} for b in batches]
    for _name, fn in [("f", bl.f_prune), ("s", bl.s_prune)]:
        pruned, info = fn(cfg, params, stats, 4)
        assert np.isfinite(eval_loss(model, pruned, eb, moe_mode="dense"))
        assert info["keep"].sum() == 4 * cfg.num_layers
    pruned, _ = bl.o_prune(cfg, params, stats, 4, samples=8)
    assert np.isfinite(eval_loss(model, pruned, eb, moe_mode="dense"))
    merged, _ = bl.m_smoe(cfg, params, stats, 4)
    assert np.isfinite(eval_loss(model, merged, eb, moe_mode="dense"))


def test_pruned_experts_never_routed(setup):
    """router_mask must keep pruned experts out of every top-k selection."""
    cfg, model, params, batches, stats = setup
    pruned, info = bl.f_prune(cfg, params, stats, 3)
    keep = info["keep"][0]
    moe_p = jax.tree.map(lambda x: x[0],
                         pruned["decoder"]["blocks"]["layer0"]["moe"])
    from repro.models.moe import router_probs

    x = np.random.RandomState(0).randn(64, cfg.d_model).astype(np.float32)
    logits = jnp.asarray(x) @ moe_p["router"] + moe_p["router_mask"]
    _, idx = router_probs(logits, cfg)
    assert keep[np.asarray(idx).ravel()].all()


def test_output_fidelity_reports(setup):
    cfg, model, params, batches, stats = setup
    merged, info = apply_hcsmoe(cfg, params, stats,
                                HCSMoEConfig(target_experts=4))
    fid = output_fidelity(model, params, merged, batches, moe_mode="dense")
    assert fid["l2_error"] >= 0 and -1 <= fid["cosine_similarity"] <= 1
    rep = cluster_quality_report(info["layers"][0]["features"],
                                 info["layers"][0]["labels"])
    assert set(rep) == {"silhouette_euc", "silhouette_cos", "dunn_euc",
                        "dunn_cos"}


def test_jensen_bound_holds_per_layer(setup):
    """Appendix A Eq. 11: with function-average merged experts
    Ē_j(x) = 1/|G_j| Σ E_i(x), the layer output error is bounded by the
    routed intra-cluster variance (the theory the paper's clustering
    objective minimises). Checked empirically on one layer."""
    cfg, model, params, batches, stats = setup
    hc = HCSMoEConfig(target_experts=3, merge="average")
    _, info = apply_hcsmoe(cfg, params, stats, hc)
    from repro.models.layers import activation
    from repro.models.moe import router_probs

    layer = info["layers"][0]
    moe_orig = jax.tree.map(lambda x: x[0],
                            params["decoder"]["blocks"]["layer0"]["moe"])
    x = jnp.asarray(np.random.RandomState(0).randn(32, cfg.d_model),
                    jnp.float32) * 0.1
    f = activation(cfg.act)
    outs = []
    for e in range(cfg.moe.num_experts):
        h = f(x @ moe_orig["wg"][e]) * (x @ moe_orig["wu"][e])
        outs.append(h @ moe_orig["wd"][e])
    outs = jnp.stack(outs, 1)  # (T, E, d)
    labels = np.asarray(layer["labels"])
    bar = jnp.stack([outs[:, labels == c].mean(1) for c in range(3)], 1)
    logits = x @ moe_orig["router"]
    probs, idx = router_probs(logits, cfg)
    t = jnp.arange(x.shape[0])
    y0 = jnp.zeros_like(x)
    y1 = jnp.zeros_like(x)
    rhs = jnp.zeros(x.shape[0])
    for k in range(cfg.moe.top_k):
        e_idx = idx[:, k]
        pk = probs[:, k, None]
        y0 = y0 + pk * outs[t, e_idx]
        merged_out = bar[t, jnp.asarray(labels)[e_idx]]
        y1 = y1 + pk * merged_out
        rhs = rhs + probs[:, k] * jnp.sum((outs[t, e_idx] - merged_out) ** 2, -1)
    # Jensen (Eq. 11) needs sum of routing weights <= 1 per token; with
    # top-k softmax weights summing to 1, ||y0-y1||^2 <= rhs holds.
    lhs = jnp.sum((y0 - y1) ** 2, -1)
    assert float(jnp.max(lhs - rhs)) <= 1e-6
