"""The Pallas kernel contract verifier (repro.analysis.kernel_verify).

The battery launches every pallas_call site in interpret mode under a
capture hook and exhaustively evaluates its BlockSpec index maps over the
full grid; a clean run proves every DMA tile is in-bounds or intentionally
clamped, tiles divide dims, scalars prefetch as ints, and out_specs tile
the output exactly once. The regression test reintroduces the PR 4
sliding-window lower-skip off-by-one and asserts the verifier flags it."""
import pytest

from repro.analysis import kernel_verify as kv


def test_battery_clean():
    results = kv.verify_all()
    assert len(results) >= 14
    bad = {name: [str(f) for f in fs] for name, fs in results.items() if fs}
    assert not bad, f"kernel contract violations: {bad}"


def test_capture_hook_sees_real_launch():
    case = next(c for c in kv.build_cases()
                if c.name == "flash_decode/w256")
    caps = []
    with kv.capture_launches(caps):
        case.run()
    assert len(caps) == 1
    cap = caps[0]
    assert cap.num_scalar_prefetch == 1
    assert len(cap.grid) == 3
    assert cap.grid[2] == 256 // 128  # W/TK kv tiles


def test_pr4_sliding_window_off_by_one_detected(monkeypatch):
    """PR 4 shipped `(ki+1)*page >= pos - window + 1` (>= for >) in the
    paged kernel's lower skip: when (pos - window) % page == page - 1 the
    gate ran a dead tile whose DMA the index map had clamped onto the last
    live page, double-counting it. Reintroduce exactly that gate and
    assert the clamp-coherence check fires on the trap case."""
    from repro.kernels import flash_decode as fd

    def buggy_live_tile_paged(ki, pos_b, *, page, window):
        run = ki * page < pos_b + 1
        if window:
            run &= (ki + 1) * page >= pos_b - window + 1  # the off-by-one
        return run

    monkeypatch.setattr(fd, "live_tile_paged", buggy_live_tile_paged)
    # p8_win12 holds pos=19: (19-12) % 8 == 7 == page-1, the trap layout
    case = next(c for c in kv.build_cases()
                if c.name == "flash_decode_paged/p8_win12")
    findings = kv.verify_case(case)
    assert findings, "verifier missed the PR 4 off-by-one"
    clamp = [f for f in findings if f.check == "clamp"]
    assert clamp, [str(f) for f in findings]
    assert any("double-count" in f.message for f in clamp)


def test_contiguous_gate_coverage_pairs_with_clamp(monkeypatch):
    """The dual failure mode: a gate that skips a REQUIRED tile (too
    aggressive rather than too lax) must trip the coverage check."""
    from repro.kernels import flash_decode as fd
    import jax.numpy as jnp

    def overeager_live_tile(ki, pos_b, *, tk, w):
        n_valid = jnp.minimum(pos_b + 1, w)
        return ki * tk < n_valid - tk  # skips the last (partial) live tile

    monkeypatch.setattr(fd, "live_tile", overeager_live_tile)
    case = next(c for c in kv.build_cases()
                if c.name == "flash_decode/w256")
    findings = kv.verify_case(case)
    assert any(f.check == "coverage" for f in findings), \
        [str(f) for f in findings]


@pytest.mark.parametrize("name", ["moe_gemm/e3", "fused_ffn/silu"])
def test_single_case_reverifies(name):
    case = next(c for c in kv.build_cases() if c.name == name)
    assert kv.verify_case(case) == []
