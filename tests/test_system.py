"""End-to-end behaviour tests: the paper's full pipeline on a *trained* tiny
MoE — train -> calibrate -> merge -> verify the qualitative claims hold
directionally, plus config/registry integrity for all 10 assigned archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config, input_specs
from repro.core import HCSMoEConfig, apply_hcsmoe, collect_moe_stats
from repro.core.quality import eval_loss
from repro.data import TokenStream
from repro.models import build_model
from repro.parallel import ParallelConfig
from repro.training import OptimizerConfig, init_opt_state, make_train_step


def test_registry_integrity():
    assert len(ASSIGNED_ARCHS) == 10
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        assert cfg.num_layers >= 1
        total, active = cfg.param_counts()
        assert active <= total
        # reduced configs construct and are small
        r = cfg.reduced()
        assert r.d_model == 64


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_build(arch, shape_name):
    """Every (arch x shape) cell has well-defined ShapeDtypeStruct inputs."""
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape_name])
    for leaf in jax.tree_util.tree_leaves(specs):
        assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
        assert all(d > 0 for d in leaf.shape)


@pytest.fixture(scope="module")
def trained_tiny_moe():
    """Train a small MoE LM for a few hundred steps on the domain-structured
    synthetic stream so experts actually specialise."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(cfg.vocab_size, seq_len=32, global_batch=8, seed=0,
                         n_domains=8)
    oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=10, total_steps=200,
                         weight_decay=0.0)
    step = jax.jit(make_train_step(
        model, oc, ParallelConfig(remat="none", moe_mode="dense")))
    opt = init_opt_state(params)
    for i in range(200):
        batch = jax.tree.map(jnp.asarray, stream.batch(i))
        params, opt, m = step(params, opt, batch)
    calib = [{"tokens": jnp.asarray(stream.batch(1000 + i)["tokens"])}
             for i in range(3)]
    evalb = [jax.tree.map(jnp.asarray, stream.batch(2000 + i))
             for i in range(4)]
    stats = collect_moe_stats(model, params, calib)
    base = eval_loss(model, params, evalb, moe_mode="dense")
    return cfg, model, params, stats, evalb, base, float(m["loss"])


def test_training_actually_learned(trained_tiny_moe):
    cfg, model, params, stats, evalb, base, final_train = trained_tiny_moe
    assert base < 5.0  # well below ln(503)=6.22 random


def test_hcsmoe_beats_random_grouping(trained_tiny_moe):
    """Output-clustered merging must beat a random grouping with the same
    merge method — the core claim that clustering quality matters."""
    cfg, model, params, stats, evalb, base, _ = trained_tiny_moe
    hc = HCSMoEConfig(target_experts=4)
    merged, info = apply_hcsmoe(cfg, params, stats, hc)
    loss_hc = eval_loss(model, merged, evalb, moe_mode="dense")

    from repro.core.pipeline import build_combine_matrix, merge_stacked_jax

    rng = np.random.RandomState(0)
    losses_rand = []
    for _trial in range(3):
        groupings = [dict(g) for g in info["layers"]]
        for g in groupings:
            labels = rng.randint(0, 4, cfg.moe.num_experts)
            labels[:4] = np.arange(4)  # surjective
            g["labels"] = labels
        m2 = jax.tree.map(lambda x: x, params)
        combine = np.stack([
            build_combine_matrix(g["labels"], g["freq"], "frequency", 4)
            for g in sorted(groupings, key=lambda g: g["block"])])
        moe = params["decoder"]["blocks"]["layer0"]["moe"]
        mg, mu, md = merge_stacked_jax(moe["wg"], moe["wu"], moe["wd"],
                                       jnp.asarray(combine))
        tgt = m2["decoder"]["blocks"]["layer0"]["moe"]
        tgt["wg"], tgt["wu"], tgt["wd"] = mg, mu, md
        tgt["group_map"] = jnp.asarray(
            np.stack([g["labels"] for g in
                      sorted(groupings, key=lambda g: g["block"])]), jnp.int32)
        losses_rand.append(eval_loss(model, m2, evalb, moe_mode="dense"))
    assert loss_hc <= min(losses_rand) + 0.02, (loss_hc, losses_rand)


def test_merge_degrades_gracefully(trained_tiny_moe):
    """More aggressive merging degrades gracefully and stays finite; r=E is
    exact identity."""
    cfg, model, params, stats, evalb, base, _ = trained_tiny_moe
    losses = {}
    for r in [8, 6, 4, 2]:
        merged, _ = apply_hcsmoe(cfg, params, stats,
                                 HCSMoEConfig(target_experts=r))
        losses[r] = eval_loss(model, merged, evalb, moe_mode="dense")
    assert abs(losses[8] - base) < 1e-4  # identity at r=E
    assert losses[2] >= losses[8] - 0.02
    assert np.isfinite(list(losses.values())).all()
