"""MergePlan artifact contract: save/load round-trips apply bit-identically,
provenance mismatches fail fast, registry validation fails at construction,
and the deprecated apply_hcsmoe shim equals apply_plan∘compute_plan."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import load_plan, save_plan
from repro.configs import get_config
from repro.core import (
    HCSMoEConfig, PlanMismatchError, PlanSpec, apply_hcsmoe, apply_plan,
    collect_moe_stats, compute_plan, plan_summary)
from repro.core import baselines as bl
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    batches = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                             (2, 32), 0, cfg.vocab_size)}
               for i in range(2)]
    stats = collect_moe_stats(model, params, batches)
    return cfg, model, params, stats


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# the full artifact grid: all four merge methods, every clustering (incl.
# fcm soft membership), every metric, non-uniform per-layer targets, and the
# prune/merge baselines
ROUNDTRIP_SPECS = [
    PlanSpec(target_experts=4),
    PlanSpec(target_experts=4, merge="average", clustering="kmeans_fix"),
    PlanSpec(target_experts=4, merge="frequency", clustering="kmeans_rnd",
             metric="weight"),
    PlanSpec(target_experts=4, merge="fix_dom"),
    PlanSpec(target_experts=4, merge="fix_dom", fix_dom_feature="weight"),
    PlanSpec(target_experts=4, merge="zipit"),
    PlanSpec(target_experts=4, clustering="fcm", resize=False),
    PlanSpec(target_experts=4, non_uniform=True, resize=False),
    PlanSpec(target_experts=4, metric="router_logits", linkage="complete"),
    PlanSpec(target_experts=3, method="f_prune"),
    PlanSpec(target_experts=3, method="s_prune"),
    PlanSpec(target_experts=2, method="o_prune", samples=8),
    PlanSpec(target_experts=4, method="m_smoe", metric="router_logits"),
]


def _spec_id(s):
    tag = f"{s.method}-{s.merge}-{s.clustering}-{s.metric}"
    return tag + ("-nonuni" if s.non_uniform else "")


@pytest.mark.parametrize("spec", ROUNDTRIP_SPECS, ids=_spec_id)
def test_roundtrip_is_bit_identical(setup, tmp_path, spec):
    """compute -> save -> load -> apply == compute -> apply, bit for bit."""
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats, spec)
    in_memory = apply_plan(params, plan)
    save_plan(str(tmp_path / "plan"), plan)
    reloaded = load_plan(str(tmp_path / "plan"))
    assert reloaded.kind == plan.kind
    assert reloaded.method == plan.method
    assert reloaded.spec == plan.spec
    assert [lp.feature_hash for lp in reloaded.layers] == \
        [lp.feature_hash for lp in plan.layers]
    _assert_trees_equal(in_memory, apply_plan(params, reloaded))


def test_reloaded_plan_serves_a_working_model(setup, tmp_path):
    cfg, model, params, stats = setup
    save_plan(str(tmp_path / "p"),
              compute_plan(cfg, params, stats, PlanSpec(target_experts=4)))
    merged = apply_plan(params, load_plan(str(tmp_path / "p")))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits, _ = model.forward(merged, tokens=toks, moe_mode="ragged")
    assert bool(np.isfinite(np.asarray(logits)).all())


@pytest.mark.parametrize("hc", [
    HCSMoEConfig(target_experts=4),
    HCSMoEConfig(target_experts=4, merge="average"),
    HCSMoEConfig(target_experts=4, merge="fix_dom"),
    HCSMoEConfig(target_experts=4, clustering="kmeans_rnd", metric="weight"),
    HCSMoEConfig(target_experts=4, clustering="fcm", resize=False),
    HCSMoEConfig(target_experts=4, non_uniform=True, resize=False),
], ids=lambda h: f"{h.merge}-{h.clustering}-{h.metric}")
def test_deprecated_shim_parity(setup, hc):
    """apply_hcsmoe == apply_plan ∘ compute_plan (pinned bit-for-bit)."""
    cfg, model, params, stats = setup
    via_shim, info = apply_hcsmoe(cfg, params, stats, hc)
    via_plan = apply_plan(params, compute_plan(cfg, params, stats, hc))
    _assert_trees_equal(via_shim, via_plan)
    # the shim surfaces the plan it computed
    assert info["plan"].num_experts == cfg.moe.num_experts


def test_prune_plan_semantics(setup):
    """Prune plans carry keep masks; applying them masks the router and
    zeroes pruned experts (same contract as the legacy baselines)."""
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats,
                        PlanSpec(target_experts=3, method="f_prune"))
    assert plan.kind == "prune"
    pruned = apply_plan(params, plan)
    legacy, info = bl.f_prune(cfg, params, stats, 3)
    _assert_trees_equal(pruned, legacy)
    moe = pruned["decoder"]["blocks"]["layer0"]["moe"]
    keep = np.asarray(plan.layers[0].keep)
    rmask = np.asarray(moe["router_mask"][0])
    assert (rmask[keep] == 0).all() and (rmask[~keep] <= -1e8).all()
    assert not np.asarray(moe["wg"][0])[~keep].any()


def test_mismatch_wrong_expert_count(setup):
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats, PlanSpec(target_experts=4))
    cfg6 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=6))
    params6 = build_model(cfg6).init(jax.random.PRNGKey(0))
    with pytest.raises(PlanMismatchError, match="experts"):
        apply_plan(params6, plan)


def test_mismatch_wrong_layer_count(setup):
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats, PlanSpec(target_experts=4))
    deeper = dataclasses.replace(cfg, num_layers=2 * cfg.num_layers)
    params2 = build_model(deeper).init(jax.random.PRNGKey(0))
    with pytest.raises(PlanMismatchError, match="block|position"):
        apply_plan(params2, plan)
    corrupt = dataclasses.replace(plan, num_layers=plan.num_layers + 1)
    with pytest.raises(PlanMismatchError, match="corrupt"):
        apply_plan(params, corrupt)


def test_validation_fails_at_construction():
    """Unknown names raise at dataclass construction (fail-fast satellite),
    listing the registered alternatives."""
    with pytest.raises(ValueError, match="expert_output"):
        HCSMoEConfig(target_experts=4, metric="nope")
    with pytest.raises(ValueError, match="hc"):
        HCSMoEConfig(target_experts=4, clustering="nope")
    with pytest.raises(ValueError, match="frequency"):
        HCSMoEConfig(target_experts=4, merge="nope")
    with pytest.raises(ValueError, match="average"):
        HCSMoEConfig(target_experts=4, linkage="nope")
    with pytest.raises(ValueError, match="act"):
        HCSMoEConfig(target_experts=4, fix_dom_feature="nope")
    with pytest.raises(ValueError, match="hc_smoe"):
        PlanSpec(target_experts=4, method="nope")
    # planner-specific constraints fail at construction too, not after a
    # full calibration pass (m_smoe only merges via combine matrices)
    with pytest.raises(ValueError, match="combine"):
        PlanSpec(target_experts=4, method="m_smoe", merge="fix_dom")


def test_executors_agree(setup):
    """The numpy reference and the sharded-jax einsum executor agree on
    combine plans (float32-tight, not bit-exact by design)."""
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats, PlanSpec(target_experts=4))
    via_jax = apply_plan(params, plan, executor="jax")
    via_np = apply_plan(params, plan, executor="numpy")
    for a, b in zip(jax.tree_util.tree_leaves(via_jax),
                    jax.tree_util.tree_leaves(via_np)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=2e-5, atol=2e-5)


def test_jax_executor_rejects_hidden_map_plans(setup):
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats,
                        PlanSpec(target_experts=4, merge="fix_dom"))
    assert plan.default_executor == "numpy"
    with pytest.raises(ValueError, match="hidden_map"):
        apply_plan(params, plan, executor="jax")


def test_apply_plan_does_not_mutate_inputs(setup):
    cfg, model, params, stats = setup
    before = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    plan = compute_plan(cfg, params, stats, PlanSpec(target_experts=4))
    apply_plan(params, plan)
    _assert_trees_equal(params, before)


def test_fcm_plan_combine_is_soft_membership(setup):
    """FCM plans bake U^T into the combine matrix (Eq. 15)."""
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats,
                        PlanSpec(target_experts=4, clustering="fcm",
                                 resize=False))
    lp = plan.layers[0]
    U = lp.extras["membership"]
    assert U.shape == (cfg.moe.num_experts, 4)
    np.testing.assert_array_equal(lp.combine[:4], U.T)
    assert not lp.combine[4:].any()  # padded rows are dead slots


def test_non_uniform_targets_recorded(setup):
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats,
                        PlanSpec(target_experts=4, non_uniform=True,
                                 resize=False))
    assert plan.slots == cfg.moe.num_experts
    for lp in plan.layers:
        assert 1 <= lp.target <= cfg.moe.num_experts
        assert int(lp.labels.max()) + 1 == lp.target


def test_plan_summary_reports_provenance(setup):
    cfg, model, params, stats = setup
    plan = compute_plan(cfg, params, stats,
                        PlanSpec(target_experts=4, seed=3))
    text = plan_summary(plan)
    for needle in ("hc_smoe", "metric=expert_output", "seed=3",
                   "feat#", "cluster_sizes="):
        assert needle in text


def test_custom_registry_entry_end_to_end(setup):
    """@register_metric extension point: a new metric becomes a valid spec
    value and drives compute_plan without touching any dispatch site."""
    cfg, model, params, stats = setup
    from repro.core.registry import METRICS, register_metric

    name = "test_only_mean_weight"
    if name not in METRICS:  # module-scoped fixture may rerun the test file
        @register_metric(name)
        def _mean_weight(st, weights):
            wg, wu, wd = weights
            return np.asarray(wg, np.float64).mean(axis=1)

    plan = compute_plan(cfg, params, stats,
                        PlanSpec(target_experts=4, metric=name))
    merged = apply_plan(params, plan)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                              cfg.vocab_size)
    logits, _ = model.forward(merged, tokens=toks, moe_mode="dense")
    assert bool(np.isfinite(np.asarray(logits)).all())
