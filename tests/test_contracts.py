"""The @checked runtime contract layer (repro.analysis.contracts).

conftest.py sets REPRO_CONTRACTS=1 before any repro import, so the
decorators on the kernel wrappers are armed for the whole suite — these
tests exercise the spec mini-language directly and the armed hot
interfaces end-to-end."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import (
    ContractError, checked, contracts_enabled)


def test_contracts_armed_by_conftest():
    assert contracts_enabled()


# ----------------------------------------------------- spec mini-language
def test_dim_unification_and_literals():
    @checked(a="B 4", b="B n", ret="B n")
    def f(a, b):
        return b

    f(np.zeros((2, 4)), np.zeros((2, 7)))
    with pytest.raises(ContractError, match="dim B=3 conflicts"):
        f(np.zeros((2, 4)), np.zeros((3, 7)))
    with pytest.raises(ContractError, match="dim 4 !="):
        f(np.zeros((2, 5)), np.zeros((2, 7)))


def test_rank_and_non_array():
    @checked(a="B n")
    def f(a):
        return a

    with pytest.raises(ContractError, match="rank 2"):
        f(np.zeros((2, 3, 4)))
    with pytest.raises(ContractError, match="expected an array"):
        f([1, 2, 3])


def test_wildcard_and_dtype_markers():
    @checked(idx="B _:int", x="_ _:float", flag="_:bool")
    def f(idx, x, flag):
        return idx

    f(np.zeros((2, 9), np.int32), np.zeros((5, 1), np.float32),
      np.zeros((3,), bool))
    with pytest.raises(ContractError, match="expected int dtype"):
        f(np.zeros((2, 9), np.float32), np.zeros((5, 1), np.float32),
          np.zeros((3,), bool))


def test_return_spec_checks_output():
    @checked(a="B n", ret="B n")
    def transpose(a):
        return a.T

    transpose(np.zeros((3, 3)))
    with pytest.raises(ContractError, match="return"):
        transpose(np.zeros((2, 5)))


def test_callable_predicate():
    @checked(mode=lambda m, _: m in ("fast", "slow"))
    def f(x, mode="fast"):
        return x

    f(1, mode="slow")
    with pytest.raises(ContractError, match="predicate"):
        f(1, mode="turbo")


def test_unknown_parameter_rejected_at_decoration():
    with pytest.raises(ContractError, match="unknown parameters"):
        @checked(nope="B")
        def f(x):
            return x


def test_checks_run_on_tracers():
    import jax

    @checked(x="B n", ret="B n")
    def f(x):
        return x * 2

    jax.jit(f)(jnp.zeros((2, 3)))  # shape metadata is static under trace
    with pytest.raises(ContractError):
        jax.jit(f)(jnp.zeros((2, 3, 4)))


# ------------------------------------------------- armed hot interfaces
def test_flash_decode_contract_armed():
    from repro.kernels.flash_decode import flash_decode

    q = jnp.zeros((2, 8, 16), jnp.float32)
    k = jnp.zeros((2, 8, 2, 16), jnp.float32)
    with pytest.raises(ContractError, match="kv_pos"):
        flash_decode(q, k, k, jnp.zeros((2, 8), jnp.float32),
                     jnp.zeros((2,), jnp.int32), interpret=True)


def test_fused_ffn_contract_armed():
    from repro.kernels.fused_ffn import fused_ffn

    x = jnp.zeros((8, 16), jnp.float32)
    wg = jnp.zeros((16, 32), jnp.float32)
    wd_bad = jnp.zeros((16, 32), jnp.float32)  # should be (F, d)
    with pytest.raises(ContractError, match="wd"):
        fused_ffn(x, wg, wg, wd_bad, interpret=True)


def test_apply_plan_contract_armed():
    from repro.core.plan import apply_plan

    with pytest.raises(ContractError, match="params"):
        apply_plan({"not_decoder": {}}, object())


# --------------------------------------------- PageAllocator invariants
def test_page_allocator_invariants_checked():
    from repro.models.kvcache import PageAllocator

    alloc = PageAllocator(num_pages=8, page_size=4)
    alloc.reserve(0, 16)
    alloc.ensure(0, 16)   # invariants asserted inline after each mutation
    alloc.ensure(1, 4)
    alloc.release(0)
    assert alloc.pages_free == 6

    # corrupt the free list the way a double-release would and assert the
    # inline check trips
    alloc._free.append(alloc._owned[1][0])
    with pytest.raises(AssertionError, match="free and mapped"):
        alloc._check_invariants()
