"""Training substrate: optimizer, schedule, grad accumulation, checkpointing,
failure/resume exactness, elastic restore, data determinism, compression."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import TokenStream, calibration_batches
from repro.models import build_model
from repro.parallel import ParallelConfig
from repro.parallel.compression import (
    compression_wire_bytes, dequantize, quantize)
from repro.training import (
    OptimizerConfig, TrainConfig, apply_updates, init_opt_state, lr_at,
    make_train_step, train)
from repro.checkpoint import CheckpointManager


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# -------------------------------------------------------------- optimizer

def test_lr_schedule_shape():
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(oc, 0)) == 0.0
    assert abs(float(lr_at(oc, 10)) - 1e-3) < 1e-9
    assert float(lr_at(oc, 100)) < float(lr_at(oc, 50)) < 1e-3
    assert float(lr_at(oc, 100)) >= 1e-4 * 0.99  # min_lr_frac floor


def test_grad_clipping(tiny):
    cfg, model, params = tiny
    huge = jax.tree.map(
        lambda p: jnp.full_like(p, 1e6)
        if jnp.issubdtype(p.dtype, jnp.floating) else None, params)
    oc = OptimizerConfig(clip_norm=1.0, peak_lr=1.0, warmup_steps=0,
                         total_steps=10, weight_decay=0.0)
    new_params, st, m = apply_updates(params, huge, init_opt_state(params), oc)
    assert m["grad_norm"] > 1e6
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(new_params))
                if jnp.issubdtype(a.dtype, jnp.floating))
    assert np.isfinite(delta) and delta < 2.0  # clipped update magnitude


def test_int_leaves_untouched(tiny):
    cfg, model, params = tiny
    grads = jax.grad(lambda p: model.train_loss(
        p, {"tokens": jnp.zeros((2, 8), jnp.int32),
            "labels": jnp.zeros((2, 8), jnp.int32)},
        moe_mode="dense", remat="none")[0], allow_int=True)(params)
    new_params, _, _ = apply_updates(params, grads, init_opt_state(params),
                                     OptimizerConfig())
    gm0 = params["decoder"]["blocks"]["layer0"]["moe"]["group_map"]
    gm1 = new_params["decoder"]["blocks"]["layer0"]["moe"]["group_map"]
    np.testing.assert_array_equal(np.asarray(gm0), np.asarray(gm1))


def test_loss_decreases_on_tiny_lm(tiny):
    cfg, model, params = tiny
    stream = TokenStream(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60,
                         weight_decay=0.0)
    step = jax.jit(make_train_step(
        model, oc, ParallelConfig(remat="none", moe_mode="dense")))
    opt = init_opt_state(params)
    losses = []
    p = params
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, stream.batch(i))
        p, opt, m = step(p, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5


def test_grad_accum_matches_full_batch(tiny):
    """Accumulated microbatch gradients == full-batch gradients for the
    linear (CE-only) loss; the optimizer-step outputs stay close (the
    load-balancing aux is nonlinear in batch statistics, and Adam amplifies
    tiny grad deltas, so the step comparison uses a loose bound)."""
    cfg, model, params = tiny
    stream = TokenStream(cfg.vocab_size, seq_len=16, global_batch=8, seed=1)
    batch = jax.tree.map(jnp.asarray, stream.batch(0))

    def ce_loss(p, b):
        return model.train_loss(p, b, moe_mode="dense", remat="none",
                                lb_coef=0.0, z_coef=0.0)[0]

    def keep_float(tree):  # drop float0 tangents of int leaves
        return [x for x in jax.tree_util.tree_leaves(tree)
                if hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                          jnp.floating)]

    g_full = keep_float(jax.grad(ce_loss, allow_int=True)(params, batch))
    micros = jax.tree.map(lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    g_acc = None
    for i in range(4):
        g_i = keep_float(jax.grad(ce_loss, allow_int=True)(
            params, jax.tree.map(lambda x, i=i: x[i], micros)))
        g_acc = g_i if g_acc is None else [a + b for a, b in zip(g_acc, g_i)]
    err = max(
        float(jnp.max(jnp.abs(a / 4.0 - b)))
        for a, b in zip(g_acc, g_full))
    assert err < 2e-5, err

    # end-to-end step path also runs (loose bound, see docstring)
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
    pc = ParallelConfig(remat="none", moe_mode="dense")
    s2 = jax.jit(make_train_step(model, oc, pc, grad_accum=4))
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    assert np.isfinite(float(m2["loss"]))


# ------------------------------------------------------------ checkpoints

def test_failure_resume_bit_exact(tiny):
    cfg, model, params = tiny
    stream = TokenStream(cfg.vocab_size, seq_len=16, global_batch=4, seed=2)
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=8)
    pc = ParallelConfig(remat="none", moe_mode="dense")
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        tc = TrainConfig(total_steps=8, ckpt_every=2, ckpt_dir=d1, log_every=4)
        p_straight, _, _ = train(model, stream, oc, tc, pc)
        tc2 = TrainConfig(total_steps=8, ckpt_every=2, ckpt_dir=d2, log_every=4)
        with pytest.raises(RuntimeError):
            train(model, stream, oc, tc2, pc, fail_at_step=5)
        p_resumed, _, _ = train(model, stream, oc, tc2, pc)
        for a, b in zip(jax.tree_util.tree_leaves(p_straight),
                        jax.tree_util.tree_leaves(p_resumed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d1)
        shutil.rmtree(d2)


def test_checkpoint_atomic_keep_k(tiny):
    cfg, model, params = tiny
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2)
        for s in [1, 2, 3, 4]:
            mgr.save(s, {"params": params, "meta": {"s": s}})
        assert mgr.all_steps() == [3, 4]
        restored, step = mgr.restore({"params": params, "meta": {}})
        assert step == 4
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_elastic_restore_across_mesh_shapes(tiny):
    """Mesh-agnostic checkpoints: save unsharded, restore with an explicit
    new sharding (the elastic-rescale path)."""
    cfg, model, params = tiny
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d)
        mgr.save(1, {"params": params})
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                                 ("data", "model"))
        shardings = {"params": jax.tree.map(
            lambda p: NamedSharding(mesh, P()), params)}
        restored, _ = mgr.restore({"params": params}, shardings=shardings)
        leaf = jax.tree_util.tree_leaves(restored["params"])[0]
        assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}
    finally:
        shutil.rmtree(d)


# ------------------------------------------------------------------ data

def test_stream_deterministic_and_shardable():
    s = TokenStream(997, seq_len=32, global_batch=8, seed=3)
    a = s.batch(5)
    b = s.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # dp sharding partitions the global batch exactly
    full = s.batch(7)["tokens"]
    parts = [s.batch(7, dp_rank=r, dp_size=4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(full, np.concatenate(parts))


def test_calibration_batches_protocol():
    cfg = get_config("mixtral-8x7b").reduced()
    batches = calibration_batches(cfg, n_seqs=8, seq_len=64, batch=4)
    assert len(batches) == 2
    assert batches[0]["tokens"].shape == (4, 64)


# ------------------------------------------------------------ compression

def test_quantize_error_feedback_converges():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(256) * 0.1, jnp.float32)
    err = jnp.zeros(256)
    acc = jnp.zeros(256)
    for _ in range(50):
        q, scale = quantize(g, err)
        deq = dequantize(q, scale)
        err = (g + err) - deq
        acc = acc + deq
    # error feedback: accumulated dequantised sum ~= accumulated true sum
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=2e-3)


def test_compression_ratio():
    g = {"a": jnp.zeros((1000,), jnp.float32), "b": jnp.zeros((50, 10), jnp.bfloat16)}
    comp, unc = compression_wire_bytes(g)
    assert comp < unc / 2.5
