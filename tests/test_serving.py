"""Serving engine: continuous batching correctness, bucketed prefill,
sampling determinism, telemetry, and merged-expert serving."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    Request, SamplingParams, ServingConfig, ServingEngine, bucket_length,
    num_buckets, supports_bucketing)
from repro.serving.bucketing import pad_prompts, plan_admission


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def merged_served(served):
    cfg, model, params = served
    from repro.core import HCSMoEConfig, run_hcsmoe

    key = jax.random.PRNGKey(3)
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                           (2, 32), 0, cfg.vocab_size)}
             for i in range(2)]
    merged, _ = run_hcsmoe(model, params, calib,
                           HCSMoEConfig(target_experts=4))
    return merged


def _greedy_reference(model, params, prompt, n_new):
    """Token-by-token greedy reference using prefill+decode directly."""
    import jax.numpy as jnp

    lp, cache = model.prefill(params, tokens=jnp.asarray(prompt[None]),
                              cache_max_len=len(prompt) + n_new + 8,
                              moe_mode="ragged")
    toks = [int(jnp.argmax(lp[0, -1]))]
    for _ in range(n_new - 1):
        ld, cache = model.decode_step(
            params, tokens=jnp.asarray([[toks[-1]]]), cache=cache,
            moe_mode="ragged")
        toks.append(int(jnp.argmax(ld[0, -1])))
    return toks


# ---------------------------------------------------------------------------
# Bucketing unit tests (no model)
# ---------------------------------------------------------------------------


class TestBucketing:
    def test_bucket_length_powers_of_two(self):
        assert bucket_length(1, min_bucket=8) == 8
        assert bucket_length(8, min_bucket=8) == 8
        assert bucket_length(9, min_bucket=8) == 16
        assert bucket_length(16, min_bucket=8) == 16
        assert bucket_length(17, min_bucket=8, max_len=64) == 32
        assert bucket_length(33, min_bucket=8, max_len=64) == 64

    def test_bucket_length_rejects_overlong(self):
        with pytest.raises(ValueError):
            bucket_length(65, max_len=64)

    def test_num_buckets_is_logarithmic(self):
        # min_bucket 8 up to 512: 8,16,32,64,128,256,512 -> 7 = log2 span + 1
        assert num_buckets(512, min_bucket=8) == 7
        assert num_buckets(8, min_bucket=8) == 1

    def test_pad_prompts_layout(self):
        prompts = [np.array([5, 6, 7], np.int32), np.array([9], np.int32)]
        tokens, last_pos = pad_prompts(prompts, batch=3, length=4)
        assert tokens.shape == (3, 4)
        np.testing.assert_array_equal(tokens[0], [5, 6, 7, 0])
        np.testing.assert_array_equal(tokens[1], [9, 0, 0, 0])
        np.testing.assert_array_equal(tokens[2], [0, 0, 0, 0])  # dummy row
        np.testing.assert_array_equal(last_pos, [2, 0, 0])

    def test_plan_admission_uses_longest_admitted(self):
        n, L = plan_admission([3, 11, 2, 60], free_slots=2, batch=4,
                              min_bucket=8, max_len=64)
        assert (n, L) == (2, 16)  # only first two admitted; max len 11 -> 16

    def test_plan_chunks_spans(self):
        from repro.serving import plan_chunks

        assert plan_chunks(20, 8) == [(0, 8), (8, 16), (16, 20)]
        assert plan_chunks(8, 8) == [(0, 8)]
        assert plan_chunks(1, 8) == [(0, 1)]
        with pytest.raises(ValueError):
            plan_chunks(0, 8)
        with pytest.raises(ValueError):
            plan_chunks(8, 0)

    def test_supports_bucketing_gate(self):
        moe_cfg = get_config("mixtral-8x7b").reduced()
        assert supports_bucketing(moe_cfg, 64)
        ssm_cfg = get_config("jamba-v0.1-52b").reduced()
        assert not supports_bucketing(ssm_cfg, 64)


# ---------------------------------------------------------------------------
# Engine correctness
# ---------------------------------------------------------------------------


def test_run_returns_every_finished_request(served):
    """Regression: run() used to declare ``finished = []`` and never append,
    silently returning [] for every workload."""
    cfg, model, params = served
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    rng = np.random.RandomState(7)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 4 + i)
                    .astype(np.int32), max_new_tokens=3) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run()
    assert sorted(r.uid for r in finished) == [r.uid for r in reqs]
    assert all(r.done for r in finished)
    assert engine.finished == finished


def test_engine_matches_unbatched_reference(served):
    """Mixed prompt lengths force real right-padding inside the buckets;
    greedy tokens must still match the exact-length unbatched reference."""
    cfg, model, params = served
    rng = np.random.RandomState(0)
    lens = [3, 6, 9, 12, 5]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    refs = [_greedy_reference(model, params, p, 5) for p in prompts]

    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    assert engine.bucket_prompts
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, ref in zip(reqs, refs):
        assert r.generated == ref, (r.uid, r.generated, ref)


def test_bucketed_prefill_compilation_count(served):
    """Many distinct prompt lengths must compile at most one prefill
    executable per power-of-two bucket: O(log2(max_len)), not O(#lengths)."""
    cfg, model, params = served
    max_len = 64
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=max_len, min_bucket=8))
    rng = np.random.RandomState(1)
    lens = list(range(2, 34, 2))  # 16 distinct lengths spanning 3 buckets
    for i, n in enumerate(lens):
        engine.submit(Request(uid=i, prompt=rng.randint(
            0, cfg.vocab_size, n).astype(np.int32), max_new_tokens=2))
    engine.run()
    bound = num_buckets(max_len, min_bucket=8)
    assert engine.prefill_compilations() <= bound, (
        engine.prefill_shapes, bound)
    # and distinct shapes are exactly the buckets the workload touched
    assert engine.prefill_shapes <= {(2, 8), (2, 16), (2, 32)}


def test_slot_reuse_and_queueing(served):
    cfg, model, params = served
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    rng = np.random.RandomState(1)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 3 for r in reqs)


def test_submit_rejects_oversized_request(served):
    cfg, model, params = served
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=16))
    with pytest.raises(ValueError, match="max_len"):
        engine.submit(Request(uid=0, prompt=np.zeros(10, np.int32),
                              max_new_tokens=10))


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------


def test_sampling_deterministic_given_seed(served):
    """Same seed -> identical tokens, independent of batch composition and
    slot assignment (key = fold_in(PRNGKey(seed), token_index))."""
    cfg, model, params = served
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=123)

    def serve(batch_slots, extra):
        engine = ServingEngine(model, params, config=ServingConfig(
            batch_slots=batch_slots, max_len=32))
        target = Request(uid=0, prompt=prompt, max_new_tokens=6, sampling=sp)
        engine.submit(target)
        for i in range(extra):  # co-tenants shuffle slot assignment
            engine.submit(Request(
                uid=100 + i,
                prompt=rng.randint(0, cfg.vocab_size, 5).astype(np.int32),
                max_new_tokens=4,
                sampling=SamplingParams(temperature=1.2, seed=77 + i)))
        engine.run()
        return target.generated

    a = serve(batch_slots=1, extra=0)
    b = serve(batch_slots=3, extra=2)
    assert a == b

    # a different seed must eventually diverge at this temperature
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=1, max_len=32))
    other = Request(uid=1, prompt=prompt, max_new_tokens=6,
                    sampling=SamplingParams(temperature=0.8, top_p=0.9,
                                            seed=124))
    engine.submit(other)
    engine.run()
    assert other.generated != a


def test_greedy_is_temperature_zero(served):
    cfg, model, params = served
    rng = np.random.RandomState(6)
    prompt = rng.randint(0, cfg.vocab_size, 4).astype(np.int32)
    ref = _greedy_reference(model, params, prompt, 4)
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=1, max_len=32))
    req = Request(uid=0, prompt=prompt, max_new_tokens=4,
                  sampling=SamplingParams(temperature=0.0))
    engine.submit(req)
    engine.run()
    assert req.generated == ref


def test_tiny_top_p_is_greedy(served):
    """top_p -> 0 keeps only the argmax token, so any temperature degrades
    to greedy decoding."""
    cfg, model, params = served
    rng = np.random.RandomState(8)
    prompt = rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
    ref = _greedy_reference(model, params, prompt, 4)
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=1, max_len=32))
    req = Request(uid=0, prompt=prompt, max_new_tokens=4,
                  sampling=SamplingParams(temperature=1.5, top_p=1e-6,
                                          seed=9))
    engine.submit(req)
    engine.run()
    assert req.generated == ref


def test_recurrent_arch_falls_back_to_exact_prefill():
    """Hybrid SSM stacks (mamba mixers) can't right-pad: the recurrent state
    would absorb the padding. The engine must auto-disable bucketing and
    still serve correctly via exact-length per-request prefill."""
    cfg = get_config("jamba-v0.1-52b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    assert not engine.bucket_prompts
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9)]
    refs = [_greedy_reference(model, params, p, 3) for p in prompts]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, ref in zip(reqs, refs):
        assert r.done and r.generated == ref, (r.uid, r.generated, ref)
    with pytest.raises(ValueError, match="not exact"):
        ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=32, bucket_prompts=True))


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------


def test_reset_stats_starts_clean(served):
    """Regression: reset_stats() left ``prefill_shapes`` populated, so the
    fallback prefill_compilations() count still included warm-up shapes
    after a reset. Post-reset stats must start from zero — including the
    compilation count, which now measures compiles SINCE the reset."""
    cfg, model, params = served
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    rng = np.random.RandomState(4)
    for i in range(3):
        engine.submit(Request(uid=i, prompt=rng.randint(
            0, cfg.vocab_size, 4 + 3 * i).astype(np.int32),
            max_new_tokens=2))
    engine.run()
    assert engine.prefill_shapes and engine.prefill_compilations() > 0

    engine.reset_stats()
    assert engine.prefill_shapes == set()
    st = engine.stats()
    assert st.requests == 0 and st.total_new_tokens == 0
    assert st.wall_time_s == 0.0 and st.tokens_per_s == 0.0
    assert st.prefill_calls == 0 and st.decode_steps == 0
    assert st.prefill_compilations == 0

    # the same workload again hits only warm executables: zero NEW compiles
    for i in range(3):
        engine.submit(Request(uid=10 + i, prompt=rng.randint(
            0, cfg.vocab_size, 4 + 3 * i).astype(np.int32),
            max_new_tokens=2))
    engine.run()
    st = engine.stats()
    assert st.requests == 3 and st.prefill_calls > 0
    if engine._jit_prefill_cache_size() is not None:
        assert st.prefill_compilations == 0, engine.prefill_shapes
    else:  # fallback counts shapes SEEN since reset (upper bound on compiles)
        assert st.prefill_compilations <= 2, engine.prefill_shapes


def test_step_driven_engine_accrues_wall_time(served):
    """Regression: wall time only accrued inside run(), so driving the
    engine via step() reported wall_time_s == 0 and tokens_per_s == 0."""
    cfg, model, params = served
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    rng = np.random.RandomState(9)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 5)
                    .astype(np.int32), max_new_tokens=3) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    for _ in range(100):
        engine.step()
        if not (engine.queue or engine.slot_live.any()):
            break
    assert all(r.done for r in reqs)
    st = engine.stats()
    assert st.wall_time_s > 0
    assert st.tokens_per_s > 0
    assert st.total_new_tokens == 9


def test_serving_stats_record(served):
    cfg, model, params = served
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    rng = np.random.RandomState(2)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run()
    st = engine.stats()
    assert st.requests == 3
    assert st.total_new_tokens == sum(len(r.generated) for r in finished) == 12
    assert st.wall_time_s > 0 and st.tokens_per_s > 0
    assert st.mean_ttft_s > 0 and st.mean_prefill_s > 0
    assert st.prefill_calls >= 1
    assert st.decode_steps >= 3
    for r in finished:
        assert r.t_submit <= r.t_admit <= r.t_first_token <= r.t_done
        assert r.ttft >= r.queue_time
        assert r.tokens_per_s > 0


def test_pad_expert_slots_skips_shared_experts():
    """Regression: pad_expert_slots matched ANY wg/wu/wd under the 'moe'
    subtree, so shared-expert FFN weights got their d/ffn dims padded and
    the forward pass crashed. Only routed (E, d, f) stacks may grow slots;
    padded slots must not change outputs."""
    import jax.numpy as jnp

    from repro.parallel import pad_expert_slots

    cfg = get_config("qwen1.5-moe-a2.7b").reduced(dtype="float32")
    assert cfg.moe.num_shared_experts > 0
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    padded = pad_expert_slots(params, 3)

    moe = params["decoder"]["blocks"]["layer0"]["moe"]
    moe_p = padded["decoder"]["blocks"]["layer0"]["moe"]
    E = cfg.moe.num_experts
    assert moe_p["wg"].shape[1] == E + (-E) % 3
    assert jax.tree.map(lambda a: a.shape, moe_p["shared"]) == \
        jax.tree.map(lambda a: a.shape, moe["shared"])

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    ref, _ = model.forward(params, tokens=toks, moe_mode="ragged")
    out, _ = model.forward(padded, tokens=toks, moe_mode="ragged")
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


# ---------------------------------------------------------------------------
# Attention backends (flash-decode serving hot path)
# ---------------------------------------------------------------------------


def test_attn_impl_pallas_token_identical(served):
    """Greedy serving must be token-identical between attn_impl='jnp' and
    'pallas' (flash-decode on every decode step, flash prefill in the
    buckets) — the acceptance criterion for the kernel swap."""
    cfg, model, params = served
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 7, 12, 5, 9)]

    def serve(impl):
        engine = ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=32, attn_impl=impl))
        assert engine.attn_impl == impl
        reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        return [r.generated for r in reqs], engine.stats()

    toks_j, st_j = serve("jnp")
    toks_p, st_p = serve("pallas")
    assert toks_j == toks_p
    # decode-step latency telemetry is populated for both backends
    assert st_j.decode_step_ms > 0 and st_p.decode_step_ms > 0
    assert st_j.decode_time_s <= st_j.wall_time_s


def test_serving_axes_composition_matrix(served):
    """All 8 combos of {contiguous,paged} x {jnp,pallas} x
    {single-device,EP} construct and serve greedy-token-identically — the
    tentpole acceptance criterion: no serving axis rejects another.

    Single-process EP here runs on a 1-device mesh (tp=1 -> kernels stay
    unpartitioned); the real 8-device paged+EP+pallas parity lives in
    tests/test_multidevice.py."""
    cfg, model, params = served
    from repro.launch.mesh import make_serving_mesh
    from repro.parallel import ParallelConfig

    rng = np.random.RandomState(21)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 7, 12, 5)]

    def serve(layout, impl, par):
        kw = {}
        if par:
            kw["parallel"] = ParallelConfig(fsdp_axis=None,
                                            weight_gather=False, ep=True)
            kw["mesh"] = make_serving_mesh()
        engine = ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=32, kv_layout=layout, attn_impl=impl,
            **kw))
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        st = engine.stats()
        assert st.kv_shard_degree >= 1
        if layout == "paged":
            assert st.kv_bytes_peak_per_device > 0
            assert st.kv_bytes_peak_per_device <= st.kv_bytes_peak
        return [r.generated for r in reqs]

    reference = serve("contiguous", "jnp", False)
    for layout in ("contiguous", "paged"):
        for impl in ("jnp", "pallas"):
            for par in (False, True):
                if (layout, impl, par) == ("contiguous", "jnp", False):
                    continue
                assert serve(layout, impl, par) == reference, \
                    f"{layout}/{impl}/{'ep' if par else 'single'} diverged"


def test_attn_impl_validated():
    with pytest.raises(ValueError, match="attn_impl"):
        get_config("mixtral-8x7b").reduced(attn_impl="einsum")


def test_pallas_engine_rounds_cache_window(served):
    """attn_impl='pallas' rounds max_len up to 128-row KV tiles so the
    flash-decode tile size never degenerates on TPU; jnp keeps it as-is."""
    cfg, model, params = served
    e = ServingEngine(model, params, config=ServingConfig(
        batch_slots=1, max_len=200, attn_impl="pallas"))
    assert e.max_len == 256
    e2 = ServingEngine(model, params, config=ServingConfig(
        batch_slots=1, max_len=200))
    assert e2.max_len == 200
    # <= 128 windows run as a single tile of any size: no rounding
    e3 = ServingEngine(model, params, config=ServingConfig(
        batch_slots=1, max_len=40, attn_impl="pallas"))
    assert e3.max_len == 40


def test_stats_report_kv_page_occupancy(served):
    """ServingStats must expose real page-pool occupancy under the paged
    layout (pages in use / peak / total, bytes vs contiguous provisioning)
    and zeros under the contiguous layout — the serving bench reports
    memory utilisation straight from these fields."""
    cfg, model, params = served
    rng = np.random.RandomState(12)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 10)
                    .astype(np.int32), max_new_tokens=3) for i in range(3)]
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32, kv_layout="paged", kv_page_size=8))
    mid_use = []
    for r in reqs:
        engine.submit(r)
    while engine.queue or engine.slot_live.any():
        engine.step()
        mid_use.append(engine.stats().kv_pages_in_use)
    st = engine.stats()
    assert st.kv_pages_total == 2 * (32 // 8)
    assert max(mid_use) == st.kv_pages_peak > 0
    assert st.kv_pages_in_use == 0          # all released at retirement
    assert 0 < st.kv_page_util <= 1.0
    assert 0 < st.kv_bytes_peak < st.kv_bytes_contiguous

    contig = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=32))
    st0 = contig.stats()
    assert st0.kv_pages_total == 0 and st0.kv_page_util == 0.0
    assert st0.kv_bytes_contiguous > 0


def test_reset_stats_clears_chunk_and_stall_counters(served):
    """reset_stats() must clear the chunked-prefill call counter and the
    max-step stall gauge, and restart the page-peak high-water mark from
    the CURRENT occupancy (not zero — resident requests still hold pages),
    so post-warm-up windows report only their own chunks and stalls."""
    cfg, model, params = served
    rng = np.random.RandomState(13)
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=64, kv_layout="paged", kv_page_size=8,
        prefill_chunk=8))
    engine.submit(Request(uid=0, prompt=rng.randint(
        0, cfg.vocab_size, 30).astype(np.int32), max_new_tokens=2))
    engine.run()
    st = engine.stats()
    assert st.prefill_chunk_calls >= 4      # 30 tokens / 8-token chunks
    assert st.max_step_s > 0

    engine.reset_stats()
    st = engine.stats()
    assert st.prefill_chunk_calls == 0 and st.max_step_s == 0.0
    assert st.kv_pages_peak == 0            # nothing resident right now

    # a request's prefill_time equals the SUM over its chunks, counted
    # once per chunk (never overwritten by the last chunk's duration)
    engine.submit(Request(uid=1, prompt=rng.randint(
        0, cfg.vocab_size, 30).astype(np.int32), max_new_tokens=2))
    engine.run()
    st = engine.stats()
    assert st.prefill_chunk_calls >= 4
    req = engine.finished[-1]
    assert req.prefill_time > 0 and st.mean_prefill_s > 0


# ---------------------------------------------------------------------------
# Merged-expert serving (the paper's deployment story)
# ---------------------------------------------------------------------------


def test_merged_model_serving_parity(served, merged_served):
    """HC-SMoE-merged params drive the same engine unchanged (group_map
    routing), and bucketed continuous batching matches the token-by-token
    merged reference exactly."""
    cfg, model, _ = served
    merged = merged_served
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 7, 10)]
    refs = [_greedy_reference(model, merged, p, 4) for p in prompts]

    engine = ServingEngine(model, merged, config=ServingConfig(
        batch_slots=2, max_len=32))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, ref in zip(reqs, refs):
        assert r.done and r.generated == ref, (r.uid, r.generated, ref)


# ---------------------------------------------------------------------------
# ServingConfig: the canonical engine configuration surface
# ---------------------------------------------------------------------------


class TestServingConfig:
    def test_config_and_kwarg_paths_are_equivalent(self, served):
        """ServingEngine(model, p, config=ServingConfig(...)) generates the
        same greedy tokens as the legacy flat-kwarg constructor."""
        cfg, model, params = served
        from repro.serving import ServingConfig

        def serve(engine):
            rng = np.random.RandomState(5)
            reqs = [Request(uid=i,
                            prompt=rng.randint(0, cfg.vocab_size, 6)
                            .astype(np.int32),
                            max_new_tokens=4) for i in range(3)]
            for r in reqs:
                engine.submit(r)
            engine.run()
            return [r.generated for r in reqs]

        with pytest.warns(DeprecationWarning, match="flat-kwarg"):
            via_kwargs = ServingEngine(model, params, batch_slots=2,
                                       max_len=32)
        via_config = ServingEngine(
            model, params, config=ServingConfig(batch_slots=2, max_len=32))
        assert serve(via_kwargs) == serve(via_config)

    def test_config_plus_kwargs_rejected(self, served):
        cfg, model, params = served
        from repro.serving import ServingConfig

        with pytest.raises(ValueError, match="not both"):
            ServingEngine(model, params, config=ServingConfig(),
                          batch_slots=2)

    def test_unknown_kwarg_rejected(self, served):
        cfg, model, params = served
        # the shim warns before ServingConfig(**kwargs) rejects the typo
        with pytest.warns(DeprecationWarning), pytest.raises(TypeError):
            ServingEngine(model, params, batch_slotz=2)

    def test_validate_is_the_canonical_incompatibility_site(self, served):
        """validate() rejects only the genuinely impossible combinations —
        bad kv_layout values, prefill_chunk without paging, paging a
        non-attention mixer — and composes everything else: paged+EP,
        pallas+EP, and paged+pallas+EP all pass validation."""
        cfg, model, params = served
        from repro.parallel import ParallelConfig
        from repro.serving import ServingConfig

        pc = ParallelConfig(fsdp_axis=None, weight_gather=False, ep=True)
        with pytest.raises(ValueError, match="kv_layout"):
            ServingConfig(kv_layout="ring").validate()
        with pytest.raises(ValueError, match="paged"):
            ServingConfig(prefill_chunk=8).validate()
        # the three serving axes compose freely: no combination of layout,
        # backend, and parallelism is rejected
        ServingConfig(kv_layout="paged", parallel=pc).validate(cfg)
        ServingConfig(attn_impl="pallas", parallel=pc).validate(cfg)
        ServingConfig(kv_layout="paged", attn_impl="pallas",
                      parallel=pc).validate(cfg)

    def test_merge_plan_applied_at_load(self, served, merged_served):
        """ServingConfig(merge_plan=...) == serving pre-merged params."""
        cfg, model, params = served
        from repro.core import HCSMoEConfig, collect_moe_stats, compute_plan
        from repro.serving import ServingConfig

        key = jax.random.PRNGKey(3)
        calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                               (2, 32), 0, cfg.vocab_size)}
                 for i in range(2)]
        stats = collect_moe_stats(model, params, calib)
        plan = compute_plan(cfg, params, stats,
                            HCSMoEConfig(target_experts=4))

        def serve(engine):
            rng = np.random.RandomState(9)
            reqs = [Request(uid=i,
                            prompt=rng.randint(0, cfg.vocab_size, 5)
                            .astype(np.int32),
                            max_new_tokens=4) for i in range(2)]
            for r in reqs:
                engine.submit(r)
            engine.run()
            return [r.generated for r in reqs]

        pre_merged = ServingEngine(model, merged_served, config=ServingConfig(
            batch_slots=2, max_len=32))
        plan_loaded = ServingEngine(
            model, params,
            config=ServingConfig(batch_slots=2, max_len=32,
                                 merge_plan=plan))
        assert serve(pre_merged) == serve(plan_loaded)
