"""Serving engine: continuous batching correctness + merged-expert serving."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new):
    """Token-by-token greedy reference using prefill+decode directly."""
    import jax.numpy as jnp

    lp, cache = model.prefill(params, tokens=jnp.asarray(prompt[None]),
                              cache_max_len=len(prompt) + n_new + 8,
                              moe_mode="ragged")
    toks = [int(jnp.argmax(lp[0, -1]))]
    for _ in range(n_new - 1):
        ld, cache = model.decode_step(
            params, tokens=jnp.asarray([[toks[-1]]]), cache=cache,
            moe_mode="ragged")
        toks.append(int(jnp.argmax(ld[0, -1])))
    return toks


def test_engine_matches_unbatched_reference(served):
    cfg, model, params = served
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]
    refs = [_greedy_reference(model, params, p, 5) for p in prompts]

    engine = ServingEngine(model, params, batch_slots=2, max_len=32,
                           moe_mode="ragged")
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, ref in zip(reqs, refs):
        assert r.generated == ref, (r.uid, r.generated, ref)


def test_slot_reuse_and_queueing(served):
    cfg, model, params = served
    engine = ServingEngine(model, params, batch_slots=2, max_len=32)
    rng = np.random.RandomState(1)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 3 for r in reqs)


def test_merged_model_serves(served):
    """HC-SMoE-merged params drive the same engine unchanged (group_map
    routing) — the paper's deployment story."""
    cfg, model, params = served
    from repro.core import HCSMoEConfig, run_hcsmoe

    key = jax.random.PRNGKey(3)
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                           (2, 32), 0, cfg.vocab_size)}
             for i in range(2)]
    merged, _ = run_hcsmoe(model, params, calib,
                           HCSMoEConfig(target_experts=4))
    engine = ServingEngine(model, merged, batch_slots=2, max_len=32)
    rng = np.random.RandomState(2)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done and len(r.generated) == 4 for r in reqs)
