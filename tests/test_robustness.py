"""Overload robustness: optimistic admission + preemption-with-recompute
token parity, terminal lifecycle (cancel/deadline/quarantine), and the
deterministic fault-injection harness (repro.serving.faults).

The central oracle: under greedy sampling, a preempted-and-recomputed
request must emit EXACTLY the tokens of an undisturbed run — preemption
releases pages, not determinism (prompt+generated replayed through the
prefill path, sampling counters resumed at len(generated))."""
import math
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    FaultConfig, Request, RequestStatus, SamplingParams, ServingConfig,
    ServingEngine)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROMPT_LENS = (3, 20, 7, 26, 11)
MAX_NEW = 5


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens=PROMPT_LENS, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _serve(model, params, prompts, max_new=MAX_NEW, sampling=None, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 64)
    eng = ServingEngine(model, params, config=ServingConfig(**kw))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new,
                    **({} if sampling is None else {"sampling": sampling[i]}))
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs, eng


@pytest.fixture(scope="module")
def baseline(served):
    """Undisturbed paged-jnp greedy tokens: the parity oracle."""
    cfg, model, params = served
    reqs, _ = _serve(model, params, _prompts(cfg),
                     kv_layout="paged", kv_page_size=8, kv_pages=32)
    return {r.uid: list(r.generated) for r in reqs}


def _tokens(reqs):
    return {r.uid: list(r.generated) for r in reqs}


# ---------------------------------------------------------------------------
# Lifecycle state machine (no model)
# ---------------------------------------------------------------------------


class TestLifecycleStateMachine:
    def test_terminal_statuses(self):
        terminal = {RequestStatus.FINISHED, RequestStatus.CANCELLED,
                    RequestStatus.EXPIRED, RequestStatus.FAILED}
        for s in RequestStatus:
            assert s.terminal == (s in terminal)

    def test_fresh_request_telemetry_is_nan(self):
        r = Request(uid=0, prompt=np.array([1, 2], np.int32),
                    max_new_tokens=2)
        assert r.status is RequestStatus.QUEUED
        assert math.isnan(r.ttft) and math.isnan(r.queue_time)
        assert math.isnan(r.tokens_per_s)

    def test_fault_config_validates(self):
        with pytest.raises(ValueError, match="preempt_every"):
            FaultConfig(preempt_every=-1).validate()
        with pytest.raises(ValueError, match="preempt_prob"):
            FaultConfig(preempt_prob=1.5).validate()
        with pytest.raises(ValueError, match="stall_s"):
            FaultConfig(stall_s=-0.1).validate()

    def test_engine_rejects_bad_admission(self, served):
        cfg, model, params = served
        with pytest.raises(ValueError, match="admission"):
            ServingEngine(model, params, config=ServingConfig(
                batch_slots=1, max_len=32, admission="pessimistic"))


# ---------------------------------------------------------------------------
# Preemption token parity (the tentpole oracle)
# ---------------------------------------------------------------------------


class TestPreemptionParity:
    def test_injected_preemption_paged_jnp(self, served, baseline):
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg),
                           kv_layout="paged", kv_page_size=8, kv_pages=32,
                           faults=FaultConfig(preempt_every=2))
        assert _tokens(reqs) == baseline
        assert all(r.status is RequestStatus.FINISHED for r in reqs)
        st = eng.stats()
        assert st.preemptions > 0
        assert st.preemptions == eng.faults.count("preempt")
        bounced = [r for r in reqs if r.preemptions]
        assert bounced, "chaos run never actually preempted anything"
        assert all(r.requeue_wait_s >= 0.0 for r in bounced)
        assert st.mean_requeue_wait_s >= 0.0

    def test_injected_preemption_paged_pallas(self, served, baseline):
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg),
                           kv_layout="paged", kv_page_size=8, kv_pages=32,
                           attn_impl="pallas",
                           faults=FaultConfig(preempt_every=3))
        assert _tokens(reqs) == baseline
        assert all(r.status is RequestStatus.FINISHED for r in reqs)
        assert eng.stats().preemptions > 0

    def test_injected_exhaustion_paged(self, served, baseline):
        """exhaust_prob makes random ensure() calls pretend the pool is
        dry: the preempt-on-exhaustion path must keep token parity."""
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg),
                           kv_layout="paged", kv_page_size=8, kv_pages=32,
                           faults=FaultConfig(seed=1, exhaust_prob=0.25))
        assert _tokens(reqs) == baseline
        assert all(r.status is RequestStatus.FINISHED for r in reqs)

    def test_stochastic_sampling_parity_under_preemption(self, served):
        """Counter-resume correctness: non-greedy streams replay across
        preemption because token i is always drawn with fold_in(seed, i),
        with the counter resumed at len(generated) on re-admission."""
        cfg, model, params = served
        sampling = [SamplingParams(temperature=0.9, top_p=0.9, seed=17 + i)
                    for i in range(len(PROMPT_LENS))]
        quiet, _ = _serve(model, params, _prompts(cfg), sampling=sampling,
                          kv_layout="paged", kv_page_size=8, kv_pages=32)
        chaos, eng = _serve(model, params, _prompts(cfg), sampling=sampling,
                            kv_layout="paged", kv_page_size=8, kv_pages=32,
                            faults=FaultConfig(preempt_every=2))
        assert eng.stats().preemptions > 0
        assert _tokens(chaos) == _tokens(quiet)

    def test_chunked_prefill_chaos(self, served):
        """Preemption mid-chunked-prefill restarts the chunk walk from the
        resume prompt; injection skips lone residents so a prefill longer
        than the injection period still terminates (livelock guard)."""
        cfg, model, params = served
        prompts = _prompts(cfg, lens=(4, 40, 6, 33), seed=7)
        kw = dict(kv_layout="paged", kv_page_size=8, kv_pages=8,
                  prefill_chunk=8)
        quiet, _ = _serve(model, params, prompts, **kw)
        chaos, eng = _serve(model, params, prompts,
                            faults=FaultConfig(seed=3, preempt_every=4),
                            **kw)
        assert all(r.status is RequestStatus.FINISHED for r in chaos)
        assert _tokens(chaos) == _tokens(quiet)


# ---------------------------------------------------------------------------
# Natural overload: optimistic admission vs reserve baseline
# ---------------------------------------------------------------------------


class TestOverload:
    def test_oversubscribed_pool_completes(self, served, baseline):
        """Aggregate worst-case demand (13 pages) far exceeds the pool
        (5): optimistic admission over-admits, preempts on exhaustion,
        recomputes, and still matches the undisturbed token streams with
        no PageExhausted escaping run()."""
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg),
                           kv_layout="paged", kv_page_size=8, kv_pages=6)
        assert _tokens(reqs) == baseline
        assert all(r.status is RequestStatus.FINISHED for r in reqs)
        st = eng.stats()
        assert st.kv_pages_in_use == 0          # pool fully drained
        assert st.kv_pages_peak <= 5

    def test_reserve_policy_never_preempts(self, served, baseline):
        """The worst-case-reservation baseline on the same oversubscribed
        workload: admission throttles instead, so zero preemptions."""
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg),
                           kv_layout="paged", kv_page_size=8, kv_pages=6,
                           admission="reserve")
        assert _tokens(reqs) == baseline
        assert eng.stats().preemptions == 0

    def test_submit_fails_fast_on_unservable_request(self, served):
        """A request whose WORST-CASE footprint exceeds the whole pool can
        never complete under any policy: reject at submit, not after
        burning pool time."""
        cfg, model, params = served
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=64, kv_layout="paged", kv_page_size=8,
            kv_pages=3))
        with pytest.raises(RuntimeError, match="kv_pages"):
            eng.submit(Request(uid=0,
                               prompt=np.arange(1, 30, dtype=np.int32),
                               max_new_tokens=20))


# ---------------------------------------------------------------------------
# Cancellation, deadlines, quarantine
# ---------------------------------------------------------------------------


class TestTerminalPaths:
    def test_cancel_running_and_queued(self, served):
        cfg, model, params = served
        prompts = _prompts(cfg, lens=(6, 9, 12))
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=1, max_len=64, kv_layout="paged", kv_page_size=8,
            kv_pages=16))
        reqs = [Request(uid=i, prompt=p, max_new_tokens=30)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.step()
        eng.step()
        assert eng.cancel(0)          # resident by now (batch_slots=1)
        assert eng.cancel(2)          # still queued
        assert not eng.cancel(99)     # unknown uid
        eng.run()
        assert reqs[0].status is RequestStatus.CANCELLED
        assert reqs[2].status is RequestStatus.CANCELLED
        assert reqs[1].status is RequestStatus.FINISHED
        assert len(reqs[2].generated) == 0
        st = eng.stats()
        assert st.cancelled == 2
        assert st.kv_pages_in_use == 0

    def test_deadline_expires_queued_request(self, served):
        cfg, model, params = served
        prompts = _prompts(cfg, lens=(6, 9))
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=1, max_len=64))
        keep = Request(uid=0, prompt=prompts[0], max_new_tokens=4,
                       deadline_s=120.0)
        drop = Request(uid=1, prompt=prompts[1], max_new_tokens=4,
                       deadline_s=0.0)
        eng.submit(keep)
        eng.submit(drop)
        eng.run()
        assert keep.status is RequestStatus.FINISHED
        assert drop.status is RequestStatus.EXPIRED
        assert len(drop.generated) == 0
        assert math.isnan(drop.ttft) and math.isnan(drop.queue_time)
        st = eng.stats()
        assert st.expired == 1
        # NaN telemetry of the expired request must not pollute the means
        assert st.mean_ttft_s > 0.0 and not math.isnan(st.mean_ttft_s)

    def test_deadline_expires_preempted_request(self, served):
        """deadline_s x preemption: the deadline clock runs from t_submit
        THROUGH preemption, so a request evicted mid-decode expires while
        requeued — with its partial generation kept and every page it
        held released exactly once."""
        cfg, model, params = served
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=1, max_len=64, kv_layout="paged", kv_page_size=8,
            kv_pages=16))
        req = Request(uid=0, prompt=_prompts(cfg, lens=(9,))[0],
                      max_new_tokens=30, deadline_s=120.0)
        eng.submit(req)
        while req.status is not RequestStatus.RUNNING or not req.generated:
            eng.step()
        eng._preempt(0)
        assert req.status is RequestStatus.QUEUED
        assert req.preemptions == 1
        partial = list(req.generated)
        assert partial, "preempted before generating anything"
        req.deadline_s = 1e-9     # long since elapsed (t_submit clock)
        eng.step()                # lifecycle sweep expires it from queue
        assert req.status is RequestStatus.EXPIRED
        assert list(req.generated) == partial
        st = eng.stats()
        assert st.expired == 1
        assert st.preemptions == 1
        assert st.kv_pages_in_use == 0, "expiry leaked (or double-freed) pages"

    def test_poisoned_logits_quarantined(self, served, baseline):
        """A NaN logit row fails ONE request; co-batched requests keep
        their exact token streams (guard masks, engine never crashes)."""
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg),
                           kv_layout="paged", kv_page_size=8, kv_pages=32,
                           faults=FaultConfig(poison_uids=(1,),
                                              poison_after=2))
        bad = next(r for r in reqs if r.uid == 1)
        assert bad.status is RequestStatus.FAILED
        assert "non-finite" in bad.error
        assert len(bad.generated) == 2      # poisoned after 2 tokens
        for r in reqs:
            if r.uid != 1:
                assert r.status is RequestStatus.FINISHED
                assert list(r.generated) == baseline[r.uid]
        st = eng.stats()
        assert st.failed == 1
        assert st.kv_pages_in_use == 0

    def test_splice_failure_fails_batch_not_engine(self, served):
        cfg, model, params = served
        prompts = _prompts(cfg, lens=(6, 9, 12, 15))
        reqs, eng = _serve(model, params, prompts,
                           kv_layout="paged", kv_page_size=8, kv_pages=32,
                           faults=FaultConfig(splice_fail_uids=(0,)))
        failed = [r for r in reqs if r.status is RequestStatus.FAILED]
        assert failed and any(r.uid == 0 for r in failed)
        assert all("splice" in r.error for r in failed)
        finished = [r for r in reqs if r.status is RequestStatus.FINISHED]
        assert finished, "engine stopped serving after a splice failure"
        st = eng.stats()
        assert st.kv_pages_in_use == 0
        assert st.failed == len(failed)

    def test_stall_injection_shows_in_telemetry(self, served):
        cfg, model, params = served
        reqs, eng = _serve(model, params, _prompts(cfg, lens=(6, 9)),
                           faults=FaultConfig(stall_steps=(0,),
                                              stall_s=0.05))
        assert all(r.status is RequestStatus.FINISHED for r in reqs)
        assert eng.faults.count("stall") == 1
        assert eng.stats().max_step_s >= 0.05


# ---------------------------------------------------------------------------
# Expert-parallel chaos (subprocess: 8 host devices)
# ---------------------------------------------------------------------------


def test_ep_preemption_token_parity():
    """Acceptance matrix: injected preemption keeps greedy token parity on
    paged x {jnp, pallas} x EP-sharded engines (single-device covered
    above). Runs in a subprocess so the main process keeps one device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent("""
        import json
        import jax
        import numpy as np
        assert len(jax.devices()) == 8
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel import ParallelConfig
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import (
            FaultConfig, Request, ServingConfig, ServingEngine)

        cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(3)
        prompts = [rng.randint(1, cfg.vocab_size, size=(n,)).astype(np.int32)
                   for n in (3, 20, 7, 26, 11)]

        def serve(**kw):
            eng = ServingEngine(model, params, config=ServingConfig(
                batch_slots=2, max_len=64, kv_layout="paged", kv_page_size=8,
                kv_pages=32, **kw))
            reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            eng.run()
            return {r.uid: list(map(int, r.generated)) for r in reqs}, eng

        ref, _ = serve()
        pc = ParallelConfig(fsdp_axis=None, weight_gather=False, ep=True)
        out = {}
        for impl in ("jnp", "pallas"):
            got, eng = serve(attn_impl=impl, parallel=pc,
                             mesh=make_serving_mesh(8),
                             faults=FaultConfig(preempt_every=3))
            out[impl] = {"match": got == ref,
                         "preemptions": eng.stats().preemptions}
        print(json.dumps(out))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for impl in ("jnp", "pallas"):
        assert res[impl]["match"], f"EP {impl} diverged under preemption"
        assert res[impl]["preemptions"] > 0
