"""Good/bad fixture pairs for every linter rule (RPR001..RPR008) plus the
noqa suppression contract. Stdlib-only module under test — no jax needed."""
import textwrap

from repro.analysis.lint import lint_source

LIB = "src/repro/core/_fixture_.py"
BENCH = "benchmarks/_fixture_.py"


def run(src, path=LIB, rule=None):
    findings = lint_source(textwrap.dedent(src), path=path)
    if rule is not None:
        findings = [f for f in findings if f.rule == rule]
    return findings


# --------------------------------------------------------------- RPR001
def test_traced_branch_flagged():
    bad = """
    import jax

    @jax.jit
    def f(x):
        if x > 0:
            return x
        return -x
    """
    assert run(bad, rule="RPR001")


def test_traced_branch_in_pallas_kernel_flagged():
    bad = """
    import functools
    from jax.experimental import pallas as pl

    def _kernel(x_ref, o_ref):
        v = x_ref[...]
        if v > 0:
            o_ref[...] = v

    def wrapper(x):
        return pl.pallas_call(functools.partial(_kernel), out_shape=x)(x)
    """
    assert run(bad, rule="RPR001")


def test_static_branches_pass():
    good = """
    import jax

    @jax.jit
    def f(x, *, causal=True):
        if causal:                 # kw-only: functools.partial static channel
            x = x + 1
        if x.shape[0] > 2:         # shapes are static at trace time
            x = x * 2
        if x is None:              # identity checks are host-side
            return x
        return x
    """
    assert not run(good, rule="RPR001")


# --------------------------------------------------------------- RPR002
def test_module_jnp_constant_flagged():
    bad = """
    import jax.numpy as jnp

    SCALE = jnp.array([1.0, 2.0])
    """
    assert run(bad, rule="RPR002")


def test_numpy_constant_and_local_jnp_pass():
    good = """
    import jax.numpy as jnp
    import numpy as np

    SCALE = np.array([1.0, 2.0])

    def f(x):
        return x * jnp.array([1.0, 2.0])
    """
    assert not run(good, rule="RPR002")


# --------------------------------------------------------------- RPR003
def test_traced_item_flagged():
    bad = """
    import jax

    @jax.jit
    def f(x):
        return x.sum().item()
    """
    assert run(bad, rule="RPR003")


def test_traced_int_cast_flagged():
    bad = """
    import jax

    @jax.jit
    def f(x):
        return int(x)
    """
    assert run(bad, rule="RPR003")


def test_host_item_passes():
    good = """
    def summarize(arr):
        return arr.sum().item()
    """
    assert not run(good, rule="RPR003")


# --------------------------------------------------------------- RPR004
def test_unknown_collective_axis_flagged():
    bad = """
    import jax

    def f(x):
        return jax.lax.psum(x, "modle")
    """
    assert run(bad, rule="RPR004")


def test_declared_axes_and_variables_pass():
    good = """
    import jax

    def f(x, axis):
        a = jax.lax.psum(x, "model")
        b = jax.lax.pmean(x, ("data", "model"))
        return a + b + jax.lax.psum(x, axis)
    """
    assert not run(good, rule="RPR004")


# --------------------------------------------------------------- RPR005
def test_unsynced_bench_timing_flagged():
    bad = """
    import time

    def bench(fn, x):
        t0 = time.time()
        out = fn(x)
        return out, time.time() - t0
    """
    assert run(bad, path=BENCH, rule="RPR005")


def test_synced_bench_timing_passes():
    good = """
    import time
    import jax

    def bench(fn, x):
        t0 = time.time()
        out = jax.block_until_ready(fn(x))
        return out, time.time() - t0
    """
    assert not run(good, path=BENCH, rule="RPR005")


def test_library_timing_not_in_scope():
    src = """
    import time

    def bench(fn, x):
        t0 = time.time()
        out = fn(x)
        return out, time.time() - t0
    """
    assert not run(src, path=LIB, rule="RPR005")


# --------------------------------------------------------------- RPR006
def test_registry_name_compare_flagged():
    # "average" is a registered merge; literal dispatch on it is the exact
    # stringly-typed pattern the registries replaced
    bad = """
    def pick(spec):
        if spec.merge == "average":
            return 1
        return 2
    """
    assert run(bad, rule="RPR006")


def test_registry_lookup_passes():
    good = """
    from repro.core.registry import MERGES

    def pick(spec):
        return MERGES.get(spec.merge)
    """
    assert not run(good, rule="RPR006")


def test_registry_rule_skips_tests_dir():
    src = """
    def pick(spec):
        return spec.merge == "average"
    """
    assert not run(src, path="src/repro/tests/test_x.py", rule="RPR006")


# --------------------------------------------------------------- RPR007
def test_print_in_library_flagged():
    assert run("print('hi')\n", rule="RPR007")


def test_print_in_launch_and_benchmarks_pass():
    assert not run("print('hi')\n", path="src/repro/launch/cli.py",
                   rule="RPR007")
    assert not run("print('hi')\n", path=BENCH, rule="RPR007")


def test_logging_passes():
    good = """
    import logging

    log = logging.getLogger(__name__)

    def f():
        log.info("hi")
    """
    assert not run(good, rule="RPR007")


# --------------------------------------------------------------- RPR008
def test_bare_except_flagged():
    bad = """
    def f():
        try:
            risky()
        except:
            handle()
    """
    assert run(bad, rule="RPR008")


def test_broad_except_pass_flagged():
    bad = """
    def f():
        try:
            risky()
        except Exception:
            pass
    """
    assert run(bad, rule="RPR008")


def test_broad_except_ellipsis_and_alias_flagged():
    bad = """
    def f():
        try:
            risky()
        except BaseException as e:
            ...
    """
    assert run(bad, rule="RPR008")


def test_narrow_or_handled_except_passes():
    good = """
    import logging

    log = logging.getLogger(__name__)

    def f():
        try:
            risky()
        except ValueError:
            pass               # narrow: caller opted into this exact case
        try:
            risky()
        except Exception:
            log.warning("risky failed; using fallback")
            return fallback()
    """
    assert not run(good, rule="RPR008")


def test_swallow_in_launch_passes():
    bad = """
    def main():
        try:
            run()
        except Exception:
            pass
    """
    assert not run(bad, path="src/repro/launch/cli.py", rule="RPR008")


def test_swallow_noqa_suppresses():
    src = """
    def f():
        try:
            risky()
        except Exception:  # noqa: RPR008
            pass
    """
    assert not run(src, rule="RPR008")


# ----------------------------------------------------------------- noqa
def test_noqa_suppresses_matching_rule():
    assert not run("print('hi')  # noqa: RPR007\n", rule="RPR007")
    assert not run("print('hi')  # noqa\n", rule="RPR007")


def test_noqa_other_rule_does_not_suppress():
    assert run("print('hi')  # noqa: RPR001\n", rule="RPR007")


def test_syntax_error_reported_not_raised():
    findings = run("def broken(:\n")
    assert findings and findings[0].rule == "RPR000"
