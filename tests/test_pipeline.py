"""GPipe pipeline parallelism: shard_map + collective_permute over stages
must reproduce the sequential stack exactly."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import apply_layer
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.pipeline import make_pipelined_stack

        cfg = dataclasses.replace(
            get_config("llama3.2-1b").reduced(dtype="float32"), num_layers=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_local_mesh((4,), ("model",))
        fwd = make_pipelined_stack(cfg, mesh, stage_axis="model")
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
        with mesh:
            y_pipe = fwd(params["decoder"]["blocks"], x, None, n_micro=4)
        positions = jnp.broadcast_to(
            jnp.arange(16, dtype=jnp.int32)[None], (8, 16))
        xx = x
        for b in range(cfg.num_blocks):
            lp = jax.tree.map(lambda v: v[b], params["decoder"]["blocks"])
            xx, _, _ = apply_layer(lp["layer0"], cfg, cfg.pattern[0], xx,
                                   positions, mode="train")
        err = float(jnp.max(jnp.abs(y_pipe - xx)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
