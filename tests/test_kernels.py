"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref, forward AND backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# grouped matmul (MoE expert GEMM)
# ---------------------------------------------------------------------------

GM_CASES = [
    # (E, d, f, N, group pattern)
    (4, 64, 128, 256, "even"),
    (4, 64, 128, 256, "skewed"),
    (8, 128, 256, 512, "with_empty"),
    (2, 32, 64, 96, "even"),
    (5, 48, 80, 200, "skewed"),
]


def _group_sizes(e, n, pattern, seed=0):
    rng = np.random.RandomState(seed)
    if pattern == "even":
        gs = np.full(e, n // e)
        gs[-1] += n - gs.sum()
    elif pattern == "skewed":
        w = rng.dirichlet(np.ones(e) * 0.3)
        gs = np.floor(w * n).astype(int)
        gs[0] += n - gs.sum()
    else:  # with_empty
        gs = np.full(e, n // (e - 2))
        gs[1] = 0
        gs[3] = 0
        gs[0] += n - gs.sum()
    assert gs.sum() == n and (gs >= 0).all()
    return jnp.asarray(gs, jnp.int32)


@pytest.mark.parametrize("e,d,f,n,pattern", GM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_forward(e, d, f, n, pattern, dtype):
    key = jax.random.PRNGKey(e * 7 + n)
    gs = _group_sizes(e, n, pattern)
    x = jax.random.normal(key, (n, d), dtype)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05
         ).astype(dtype)
    y = ops.grouped_matmul(x, w, gs)
    y_ref = ref.grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("e,d,f,n,pattern", GM_CASES[:3])
def test_grouped_matmul_backward(e, d, f, n, pattern):
    key = jax.random.PRNGKey(3)
    gs = _group_sizes(e, n, pattern)
    x = jax.random.normal(key, (n, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05

    def lk(x, w):
        return jnp.sum(jnp.sin(ops.grouped_matmul(x, w, gs)))

    def lr(x, w):
        return jnp.sum(jnp.sin(ref.grouped_matmul_ref(x, w, gs)))

    gx_k, gw_k = jax.grad(lk, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(lr, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r),
                               rtol=1e-4, atol=1e-4)


def test_grouped_ffn_matches_ref():
    key = jax.random.PRNGKey(5)
    e, d, f, n = 4, 64, 96, 256
    gs = _group_sizes(e, n, "skewed")
    x = jax.random.normal(key, (n, d))
    ws = [jax.random.normal(jax.random.fold_in(key, i), s) * 0.05
          for i, s in enumerate([(e, d, f), (e, d, f), (e, f, d)])]
    y = ops.grouped_ffn(x, *ws, gs)
    y_ref = ref.grouped_ffn_ref(x, *ws, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    (1, 128, 2, 32, True),
    (2, 256, 4, 64, True),
    (2, 256, 4, 64, False),
    (1, 512, 1, 128, True),
    (3, 128, 2, 16, True),
]


@pytest.mark.parametrize("b,s,h,hd,causal", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, hd, causal, dtype):
    key = jax.random.PRNGKey(b * 31 + s)
    q = jax.random.normal(key, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd), dtype)
    o = ops.flash_attention(q, k, v, causal=causal)
    o_ref = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# fused FFN
# ---------------------------------------------------------------------------

FFN_CASES = [
    (128, 64, 256, "silu"),
    (256, 128, 128, "gelu"),
    (64, 32, 512, "silu"),
]


@pytest.mark.parametrize("m,d,f,act", FFN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ffn(m, d, f, act, dtype):
    key = jax.random.PRNGKey(m + f)
    x = jax.random.normal(key, (m, d), dtype)
    wg = (jax.random.normal(jax.random.fold_in(key, 1), (d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(jax.random.fold_in(key, 2), (d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(jax.random.fold_in(key, 3), (f, d)) * 0.05).astype(dtype)
    y = ops.fused_ffn(x, wg, wu, wd, act)
    y_ref = ref.fused_ffn_ref(x, wg, wu, wd, act)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))


def test_padded_layout_properties():
    """padded_layout invariants: dest indices unique, tiles map to the right
    expert, unfilled rows land in the owning expert's padding."""
    from repro.kernels.moe_gemm import TILE_N, padded_layout

    gs = jnp.asarray([3, 0, 260, 129], jnp.int32)
    n = int(gs.sum())
    dest, tile_expert, n_pad = padded_layout(gs, n)
    dest = np.asarray(dest)
    assert len(set(dest.tolist())) == n  # injective
    te = np.asarray(tile_expert)
    padded = np.ceil(np.asarray(gs) / TILE_N).astype(int) * TILE_N
    offs = np.concatenate([[0], np.cumsum(padded)[:-1]])
    # each token's padded row lies in a tile owned by its expert
    expert_of = np.repeat(np.arange(4), np.asarray(gs))
    for t, e in zip(dest, expert_of):
        assert te[t // TILE_N] == e
