import os

# Smoke tests and benches see ONE device; multi-device tests run in
# subprocesses that set xla_force_host_platform_device_count themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
