import os

# Smoke tests and benches see ONE device; multi-device tests run in
# subprocesses that set xla_force_host_platform_device_count themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Arm the @checked runtime contracts (repro.analysis.contracts) for the
# whole suite. Must happen before any repro import: the decorator reads the
# flag at import time so production paths stay a zero-cost identity.
os.environ.setdefault("REPRO_CONTRACTS", "1")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
