"""Merging-layer tests: convexity, identity cases, fix-dom permutation
equivariance, and the sharded-jax merge vs the numpy reference."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.merging import cluster_alphas, merge_layer
from repro.core.pipeline import build_combine_matrix, merge_stacked_jax

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def _weights(E=6, d=8, f=10, seed=0):
    rng = np.random.RandomState(seed)
    return (rng.randn(E, d, f).astype(np.float32),
            rng.randn(E, d, f).astype(np.float32),
            rng.randn(E, f, d).astype(np.float32))


@given(st.integers(2, 8), st.integers(0, 30),
       st.sampled_from(["average", "frequency"]))
def test_alphas_form_simplex_per_cluster(E, seed, method):
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, max(1, E // 2), E)
    labels[0] = 0
    freq = rng.rand(E) * 10
    alphas = cluster_alphas(labels, freq, method)
    for c in np.unique(labels):
        np.testing.assert_allclose(alphas[labels == c].sum(), 1.0, atol=1e-9)
    assert (alphas >= 0).all()


@pytest.mark.parametrize("E,seed,method", [
    (2, 0, "average"), (4, 3, "average"), (8, 11, "average"),
    (3, 1, "frequency"), (6, 7, "frequency"), (8, 29, "frequency")])
def test_alphas_form_simplex_plain(E, seed, method):
    """Fixed-seed version of the property test above — runs without
    hypothesis installed."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, max(1, E // 2), E)
    labels[0] = 0
    freq = rng.rand(E) * 10
    alphas = cluster_alphas(labels, freq, method)
    for c in np.unique(labels):
        np.testing.assert_allclose(alphas[labels == c].sum(), 1.0, atol=1e-9)
    assert (alphas >= 0).all()


def test_singleton_clusters_are_identity():
    wg, wu, wd = _weights()
    labels = np.arange(6)
    freq = np.ones(6)
    for method in ["average", "frequency", "fix_dom"]:
        act = np.random.RandomState(0).randn(6, 4, 10)
        g, u, d, gm = merge_layer(wg, wu, wd, labels, freq, method,
                                  act_sample=act)
        np.testing.assert_allclose(g, wg, atol=1e-6)
        np.testing.assert_allclose(u, wu, atol=1e-6)
        np.testing.assert_allclose(d, wd, atol=1e-6)


def test_average_merge_of_identical_experts_is_identity():
    wg, wu, wd = _weights(E=1)
    wg = np.repeat(wg, 4, 0)
    wu = np.repeat(wu, 4, 0)
    wd = np.repeat(wd, 4, 0)
    labels = np.zeros(4, np.int64)
    g, u, d, _ = merge_layer(wg, wu, wd, labels, np.ones(4), "average")
    np.testing.assert_allclose(g[0], wg[0], atol=1e-6)
    np.testing.assert_allclose(d[0], wd[0], atol=1e-6)


def test_frequency_merge_weights_by_usage():
    wg, wu, wd = _weights(E=2)
    labels = np.zeros(2, np.int64)
    freq = np.array([3.0, 1.0])
    g, _, _, _ = merge_layer(wg, wu, wd, labels, freq, "frequency")
    np.testing.assert_allclose(g[0], 0.75 * wg[0] + 0.25 * wg[1], atol=1e-6)


def test_fix_dom_identical_experts_identity():
    """If all experts in a cluster are identical, fix-dom must return the
    expert itself (correlation map = identity, averaging a constant)."""
    wg, wu, wd = _weights(E=1, seed=3)
    wg = np.repeat(wg, 3, 0)
    wu = np.repeat(wu, 3, 0)
    wd = np.repeat(wd, 3, 0)
    act = np.repeat(np.random.RandomState(1).randn(1, 16, 10), 3, 0)
    g, u, d, _ = merge_layer(wg, wu, wd, np.zeros(3, np.int64),
                             np.array([2.0, 1.0, 1.0]), "fix_dom",
                             act_sample=act)
    np.testing.assert_allclose(g[0], wg[0], atol=1e-5)
    np.testing.assert_allclose(d[0], wd[0], atol=1e-5)


def test_jax_merge_matches_numpy_reference():
    wg, wu, wd = _weights(E=6)
    labels = np.array([0, 0, 1, 2, 1, 2])
    freq = np.array([5.0, 1.0, 2.0, 2.0, 0.0, 3.0])
    g_np, u_np, d_np, _ = merge_layer(wg, wu, wd, labels, freq, "frequency")
    combine = build_combine_matrix(labels, freq, "frequency", 3)
    g_j, u_j, d_j = merge_stacked_jax(
        jnp.asarray(wg)[None], jnp.asarray(wu)[None], jnp.asarray(wd)[None],
        jnp.asarray(combine)[None])
    np.testing.assert_allclose(np.asarray(g_j[0]), g_np, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(u_j[0]), u_np, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(d_j[0]), d_np, rtol=2e-5, atol=2e-5)


@given(st.integers(0, 20))
def test_zipit_shapes(seed):
    wg, wu, wd = _weights(E=4, d=6, f=8, seed=seed)
    labels = np.array([0, 0, 1, 1])
    act = np.random.RandomState(seed).randn(4, 12, 8)
    g, u, d, _ = merge_layer(wg, wu, wd, labels, np.ones(4), "zipit",
                             act_sample=act)
    assert g.shape == (2, 6, 8) and d.shape == (2, 8, 6)
    assert np.isfinite(g).all() and np.isfinite(d).all()


@pytest.mark.parametrize("seed", [0, 4, 17])
def test_zipit_shapes_plain(seed):
    wg, wu, wd = _weights(E=4, d=6, f=8, seed=seed)
    labels = np.array([0, 0, 1, 1])
    act = np.random.RandomState(seed).randn(4, 12, 8)
    g, u, d, _ = merge_layer(wg, wu, wd, labels, np.ones(4), "zipit",
                             act_sample=act)
    assert g.shape == (2, 6, 8) and d.shape == (2, 8, 6)
    assert np.isfinite(g).all() and np.isfinite(d).all()
