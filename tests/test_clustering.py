"""Unit + property tests for the clustering layer (paper §3.2.2 / App. A)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.clustering import (
    fcm_cluster, hierarchical_cluster, kmeans_cluster, pairwise_euclidean)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _feats(n, d, seed=0, clusters=None):
    rng = np.random.RandomState(seed)
    if clusters is None:
        return rng.randn(n, d)
    # well-separated blobs
    centers = rng.randn(clusters, d) * 30
    return np.concatenate(
        [centers[i % clusters] + 0.01 * rng.randn(1, d) for i in range(n)])


class TestHierarchical:
    def test_recovers_separated_blobs(self):
        feats = _feats(12, 8, clusters=3)
        labels = hierarchical_cluster(feats, 3, "average")
        # same blob -> same label
        for i in range(12):
            for j in range(12):
                same_blob = (i % 3) == (j % 3)
                assert (labels[i] == labels[j]) == same_blob

    @pytest.mark.parametrize("linkage", ["single", "complete", "average"])
    def test_linkages_valid_partition(self, linkage):
        feats = _feats(10, 4, seed=3)
        labels = hierarchical_cluster(feats, 4, linkage)
        assert labels.shape == (10,)
        assert set(labels) == set(range(4))

    def test_deterministic(self):
        feats = _feats(16, 6, seed=5)
        a = hierarchical_cluster(feats, 5, "average")
        b = hierarchical_cluster(feats, 5, "average")
        assert np.array_equal(a, b)

    def test_matches_scipy_average_linkage(self):
        scipy = pytest.importorskip("scipy.cluster.hierarchy")
        feats = _feats(14, 5, seed=7)
        ours = hierarchical_cluster(feats, 4, "average")
        Z = scipy.linkage(feats, method="average", metric="euclidean")
        theirs = scipy.fcluster(Z, t=4, criterion="maxclust")
        # same partition up to relabeling
        mapping = {}
        for o, t in zip(ours, theirs):
            mapping.setdefault(o, t)
            assert mapping[o] == t

    @given(st.integers(2, 12), st.integers(1, 8), st.integers(0, 100))
    def test_property_r_clusters(self, n, r_raw, seed):
        r = min(r_raw, n)
        feats = np.random.RandomState(seed).randn(n, 3)
        labels = hierarchical_cluster(feats, r, "average")
        assert len(set(labels)) == r
        assert labels.min() == 0 and labels.max() == r - 1

    @given(st.integers(0, 50))
    def test_property_identical_points_merge_first(self, seed):
        rng = np.random.RandomState(seed)
        base = rng.randn(5, 4) * 10
        feats = np.concatenate([base, base[:1] + 1e-9])  # duplicate of row 0
        labels = hierarchical_cluster(feats, 5, "average")
        assert labels[0] == labels[5]

    def test_r_equals_n_is_identity(self):
        feats = _feats(8, 3)
        labels = hierarchical_cluster(feats, 8, "average")
        assert sorted(labels) == list(range(8))

    def test_r_equals_one(self):
        feats = _feats(6, 3)
        assert set(hierarchical_cluster(feats, 1, "single")) == {0}


class TestPlainProperties:
    """Fixed-seed parametrized versions of the property tests above, so the
    core invariants stay covered when hypothesis is not installed."""

    @pytest.mark.parametrize("n,r,seed", [(2, 1, 0), (5, 3, 1), (8, 8, 2),
                                          (12, 4, 7), (9, 2, 13), (6, 5, 42)])
    def test_r_clusters(self, n, r, seed):
        feats = np.random.RandomState(seed).randn(n, 3)
        labels = hierarchical_cluster(feats, r, "average")
        assert len(set(labels)) == r
        assert labels.min() == 0 and labels.max() == r - 1

    @pytest.mark.parametrize("seed", [0, 7, 23, 31])
    def test_identical_points_merge_first(self, seed):
        rng = np.random.RandomState(seed)
        base = rng.randn(5, 4) * 10
        feats = np.concatenate([base, base[:1] + 1e-9])
        labels = hierarchical_cluster(feats, 5, "average")
        assert labels[0] == labels[5]


class TestKMeansAndFCM:
    def test_kmeans_fix_deterministic(self):
        feats = _feats(12, 4, seed=2)
        assert np.array_equal(kmeans_cluster(feats, 3, "fix"),
                              kmeans_cluster(feats, 3, "fix"))

    def test_kmeans_rnd_seed_sensitivity_exists(self):
        # the paper's instability claim: different seeds CAN give different
        # partitions on ambiguous data
        feats = _feats(20, 6, seed=9)
        results = {tuple(kmeans_cluster(feats, 6, "rnd", seed=s))
                   for s in range(8)}
        assert len(results) >= 2

    def test_kmeans_nonempty_clusters(self):
        feats = _feats(10, 3, seed=4)
        labels = kmeans_cluster(feats, 5, "rnd", seed=1)
        assert len(set(labels)) == 5

    def test_kmeans_reseeds_distinct_points_for_multiple_empty_clusters(self):
        """Regression: with several empty clusters and one dominant outlier,
        the old reseeding picked the SAME farthest point for every empty
        cluster (later assignments overwrote earlier ones), collapsing the
        partition. Four near-identical points + one outlier with r=4 must
        still yield 4 non-empty clusters."""
        feats = np.array([[0.0, 0.0], [0.0, 1e-6], [1e-6, 0.0],
                          [1e-6, 1e-6], [100.0, 100.0]])
        labels = kmeans_cluster(feats, 4, "fix")
        assert len(set(labels)) == 4
        assert labels.min() == 0 and labels.max() == 3

    @pytest.mark.parametrize("n,r,seed", [(6, 5, 0), (10, 7, 3), (8, 8, 5)])
    def test_kmeans_always_r_nonempty_clusters(self, n, r, seed):
        # degenerate data (many duplicates) maximises empty-cluster pressure
        rng = np.random.RandomState(seed)
        feats = np.repeat(rng.randn(max(2, (n + 2) // 3), 2), 3, axis=0)[:n]
        labels = kmeans_cluster(feats, r, "rnd", seed=seed)
        assert len(set(labels)) == r

    def test_fcm_membership_rows_sum_to_one(self):
        feats = _feats(9, 4, seed=6)
        labels, U = fcm_cluster(feats, 3, seed=0)
        assert U.shape == (9, 3)
        np.testing.assert_allclose(U.sum(1), 1.0, atol=1e-6)
        assert np.array_equal(labels, np.argmax(U, axis=1))


def test_pairwise_euclidean_matches_numpy():
    feats = _feats(7, 5, seed=11)
    D = pairwise_euclidean(feats)
    for i in range(7):
        for j in range(7):
            assert abs(D[i, j] - np.linalg.norm(feats[i] - feats[j])) < 1e-6
