"""Cross-request prefix caching: chain-hash key derivation, refcounted
allocator + copy-on-write invariants (plain + hypothesis property tests),
and engine-level greedy token parity with caching on vs off across both
attention backends, single-device and EP, including under forced
preemption of a warm-prefix request."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.kvcache import (
    PageAllocator, PageExhausted, prefix_keys)
from repro.serving import (
    Request, SamplingParams, ServingConfig, ServingEngine)
from tests._hyp import HAVE_HYPOTHESIS, given, settings, st

if HAVE_HYPOTHESIS:
    prop_settings = settings(max_examples=50, deadline=None)
else:  # decorators evaluate even under skipif; the shim settings is inert
    def prop_settings(f):
        return f


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# prefix_keys: chain-hash candidate derivation
# ---------------------------------------------------------------------------


class TestPrefixKeys:
    def test_candidates_at_page_boundaries_plus_maximal(self):
        toks = np.arange(18, dtype=np.int32)
        cands = prefix_keys(toks, page_size=8)
        assert [n for n, _ in cands] == [8, 16, 17]  # 17 = len - 1 maximal

    def test_no_maximal_when_len_minus_one_on_boundary(self):
        cands = prefix_keys(np.arange(17, dtype=np.int32), page_size=8)
        assert [n for n, _ in cands] == [8, 16]

    def test_always_leaves_one_suffix_token(self):
        # every candidate claims <= len-1 rows: the engine must run at
        # least one token through extend to get first-token logits
        for n in (1, 2, 8, 9, 31):
            cands = prefix_keys(np.arange(n, dtype=np.int32), page_size=8)
            assert all(rows <= n - 1 for rows, _ in cands)
        assert prefix_keys(np.arange(1, dtype=np.int32), page_size=8) == []

    def test_key_commits_to_entire_prefix(self):
        a = np.arange(24, dtype=np.int32)
        b = a.copy()
        b[0] += 1  # perturb only the FIRST token
        ka = dict((n, k) for n, k in prefix_keys(a, page_size=8))
        kb = dict((n, k) for n, k in prefix_keys(b, page_size=8))
        assert ka.keys() == kb.keys()
        assert all(ka[n] != kb[n] for n in ka)  # chain propagates

    def test_page_size_folded_into_chain_root(self):
        toks = np.arange(9, dtype=np.int32)
        k4 = dict(prefix_keys(toks, page_size=4))
        k8 = dict(prefix_keys(toks, page_size=8))
        assert k4[8] != k8[8]  # same span, different pool geometry


# ---------------------------------------------------------------------------
# PageAllocator with prefix caching (plain invariant tests)
# ---------------------------------------------------------------------------


def _publish(a, slot, toks):
    """Admit ``slot`` cold for ``toks`` and publish its prefix pages."""
    a.ensure(slot, len(toks))
    a.register_prefix(slot, prefix_keys(toks, a.page_size))


class TestPrefixAllocator:
    def test_splice_increfs_release_never_frees_shared(self):
        a = PageAllocator(num_pages=9, page_size=4, prefix_cache=True)
        toks = np.arange(9, dtype=np.int32)
        _publish(a, 0, toks)
        entry = a.match_prefix(prefix_keys(toks, 4))
        assert entry is not None and entry.n_rows == 8
        pages = a.splice_prefix(1, entry)
        assert all(a.refs(p) == 2 for p in pages)
        assert a.pages_in_use == 3  # 2 shared once + publisher's 3rd page
        third = a.owned(0)[2]       # beyond the entry: uncached, unshared
        assert a.release(0) == [third]  # shared pages survive slot 1...
        assert all(a.refs(p) == 1 for p in pages)
        assert a.release(1) == []   # ...then stay resident as warm cache
        assert a.pages_cached == 2 and a.pages_in_use == 0

    def test_cow_never_aliases_a_writable_page(self):
        a = PageAllocator(num_pages=9, page_size=4, prefix_cache=True)
        toks = np.arange(9, dtype=np.int32)
        _publish(a, 0, toks)
        a.splice_prefix(1, a.match_prefix(prefix_keys(toks, 4)))
        old, new = a.cow(1, 1)
        assert old != new
        assert a.refs(new) == 1 and not a.page_shared(new)
        assert a.owned(0)[1] == old  # publisher's mapping untouched
        assert a.owned(1)[1] == new
        # the publisher's copy is still cached -> still needs COW to write
        assert a.page_shared(old)

    def test_evict_then_rehash_round_trips(self):
        a = PageAllocator(num_pages=6, page_size=4, prefix_cache=True)
        toks = np.arange(9, dtype=np.int32)
        _publish(a, 0, toks)
        a.release(0)
        assert a.pages_cached == 2
        # allocation pressure evicts the LRU entries and frees their pages
        a.ensure(1, 20)  # all 5 allocatable pages
        assert a.pages_cached == 0 and a.prefix_entries == 0
        assert sorted(a.drain_evicted()) == sorted(a.owned(1)[:2])
        assert a.match_prefix(prefix_keys(toks, 4)) is None
        a.release(1)
        # re-admit + re-register the SAME tokens: keys match by
        # construction, the cache warms right back up
        _publish(a, 2, toks)
        entry = a.match_prefix(prefix_keys(toks, 4))
        assert entry is not None and entry.n_rows == 8

    def test_pressure_never_frees_referenced_pages(self):
        a = PageAllocator(num_pages=6, page_size=4, prefix_cache=True)
        toks = np.arange(9, dtype=np.int32)
        _publish(a, 0, toks)       # slot 0 resident AND cached (3 pages)
        before = a.owned(0)
        with pytest.raises(PageExhausted):
            a.ensure(1, 20)  # needs 5; 2 free + 0 evictable (every cached
            #                  page is still MAPPED by slot 0)
        # the pool refuses rather than freeing referenced pages — slot 0's
        # claim and its published entry are both intact
        assert a.owned(0) == before
        assert all(a.refs(p) == 1 for p in before)
        assert a.drain_evicted() == []
        assert a.match_prefix(prefix_keys(toks, 4)) is not None

    def test_prefix_cache_pages_caps_resident_footprint(self):
        a = PageAllocator(num_pages=12, page_size=4, prefix_cache=True,
                          prefix_cache_pages=2)
        _publish(a, 0, np.arange(9, dtype=np.int32))
        _publish(a, 1, np.arange(100, 109, dtype=np.int32))
        a.release(0)
        a.release(1)
        assert a.pages_cached <= 2

    def test_match_prefix_touch_false_keeps_lru_order(self):
        a = PageAllocator(num_pages=8, page_size=4, prefix_cache=True)
        old = np.arange(9, dtype=np.int32)
        new = np.arange(50, 59, dtype=np.int32)
        _publish(a, 0, old)
        _publish(a, 1, new)
        a.release(0)
        a.release(1)
        a.match_prefix(prefix_keys(old, 4), touch=False)  # probe only
        a.ensure(2, 16)  # 4 pages vs 3 free: evicts the LRU entry's pages
        # the probed-but-untouched OLD entry was evicted first
        assert a.match_prefix(prefix_keys(old, 4)) is None
        assert a.match_prefix(prefix_keys(new, 4)) is not None


# ---------------------------------------------------------------------------
# PageAllocator with prefix caching (hypothesis property tests)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestPrefixAllocatorProperties:
    @prop_settings
    @given(st.integers(min_value=6, max_value=40),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=0, max_value=2 ** 60))
    def test_random_lifecycles_keep_refcount_invariants(
            self, num_pages, page_size, seed):
        """Arbitrary interleavings of cold admission, publish, warm splice,
        COW, and release: no page is freed while a slot still maps it, COW
        targets are never shared, and the free/mapped/cached partition
        never leaks. REPRO_CONTRACTS=1 (tests/conftest.py) additionally
        arms the allocator's inline ``_check_invariants`` on every op."""
        rng = np.random.RandomState(seed % (2 ** 32))
        a = PageAllocator(num_pages, page_size, prefix_cache=True)
        live = {}  # slot -> prompt tokens
        prompts = [np.asarray(rng.randint(0, 50, n), np.int32)
                   for n in rng.randint(2, 4 * page_size + 2, size=5)]
        for _ in range(80):
            op = rng.rand()
            s = int(rng.randint(0, 6))
            if op < 0.35 and s not in live:          # admit (warm or cold)
                toks = prompts[rng.randint(len(prompts))]
                cands = prefix_keys(toks, page_size)
                entry = a.match_prefix(cands)
                try:
                    if entry is not None:
                        pages = a.splice_prefix(s, entry)
                        assert all(a.refs(p) >= 1 for p in pages)
                        a.ensure(s, len(toks))
                    else:
                        a.ensure(s, len(toks))
                        a.register_prefix(s, cands)
                    live[s] = toks
                except PageExhausted:
                    a.release(s)  # roll back a half-admitted slot
            elif op < 0.55 and live:                 # COW a random page
                s = sorted(live)[rng.randint(len(live))]
                owned = a.owned(s)
                li = int(rng.randint(len(owned)))
                if a.page_shared(owned[li]):
                    try:
                        old, new = a.cow(s, li)
                    except PageExhausted:
                        continue
                    assert old != new
                    assert a.refs(new) == 1
                    assert not a.page_shared(new)
            elif live:                               # retire / preempt
                s = sorted(live)[rng.randint(len(live))]
                freed = a.release(s)
                del live[s]
                assert all(a.refs(p) == 0 for p in freed)
                mapped = {p for t in live for p in a.owned(t)}
                assert not set(freed) & mapped, (
                    "released a page another slot still maps")
            a.drain_evicted()
            assert a.pages_free + a.pages_in_use + a.pages_cached \
                == a.num_pages - 1
            assert a.pages_available >= 0

    @prop_settings
    @given(st.integers(min_value=1, max_value=8),
           st.integers(min_value=2, max_value=40),
           st.integers(min_value=0, max_value=2 ** 32 - 1))
    def test_evict_then_rehash_round_trips(self, page_size, prompt_len,
                                           seed):
        """Publishing, evicting (via pressure), and re-publishing the same
        tokens always reproduces a matchable entry of the same n_rows —
        keys are pure functions of (tokens, page_size)."""
        rng = np.random.RandomState(seed)
        toks = np.asarray(rng.randint(0, 1000, prompt_len), np.int32)
        cands = prefix_keys(toks, page_size)
        pool = PageAllocator(
            num_pages=2 * max(1, -(-prompt_len // page_size)) + 2,
            page_size=page_size, prefix_cache=True)
        _publish(pool, 0, toks)
        first = pool.match_prefix(cands)
        pool.release(0)
        pool.ensure(1, (pool.num_pages - 1) * page_size)  # evict everything
        pool.release(1)
        pool.drain_evicted()
        assert pool.match_prefix(cands) is None
        _publish(pool, 2, toks)
        again = pool.match_prefix(cands)
        if first is None:
            assert again is None  # 1-token prompts have no candidates
        else:
            assert again is not None and again.n_rows == first.n_rows


# ---------------------------------------------------------------------------
# Engine: greedy token parity, warm TTFT, preemption of warm requests
# ---------------------------------------------------------------------------


def _shared_prefix_prompts(cfg, rng, n, prefix_len, page):
    shared = rng.randint(0, cfg.vocab_size, prefix_len).astype(np.int32)
    return [np.concatenate(
                [shared, rng.randint(0, cfg.vocab_size, 2 + i
                                     ).astype(np.int32)])
            for i in range(n)]


def _serve_prefix(model, params, prompts, *, prefix_cache, impl="jnp",
                  par=False, kv_pages=None, max_new=4):
    kw = {}
    if par:
        from repro.launch.mesh import make_serving_mesh
        from repro.parallel import ParallelConfig

        kw["parallel"] = ParallelConfig(fsdp_axis=None,
                                        weight_gather=False, ep=True)
        kw["mesh"] = make_serving_mesh()
    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=2, max_len=64, kv_layout="paged", kv_page_size=8,
        attn_impl=impl, prefix_cache=prefix_cache, kv_pages=kv_pages, **kw))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    return [list(map(int, r.generated)) for r in reqs], engine.stats()


def test_engine_prefix_parity_matrix(served):
    """Greedy tokens are bit-identical with prefix caching on vs off
    across {jnp,pallas} x {single-device,EP} on a shared-prefix workload,
    and every cached run actually exercises the cache (hits > 0). The
    acceptance matrix for the prefix-reuse tentpole."""
    cfg, model, params = served
    rng = np.random.RandomState(33)
    prompts = _shared_prefix_prompts(cfg, rng, 4, prefix_len=20, page=8)

    reference, _ = _serve_prefix(model, params, prompts, prefix_cache=False)
    for impl in ("jnp", "pallas"):
        for par in (False, True):
            off, _ = _serve_prefix(model, params, prompts,
                                   prefix_cache=False, impl=impl, par=par)
            on, st = _serve_prefix(model, params, prompts,
                                   prefix_cache=True, impl=impl, par=par)
            tag = f"{impl}/{'ep' if par else 'single'}"
            assert off == reference, f"cache-off {tag} diverged"
            assert on == reference, f"cache-on {tag} diverged"
            # 4 requests through 2 slots: later admissions hit the prefix
            # the first wave published
            assert st.prefix_hits > 0, f"{tag} never hit the cache"
            assert st.prefix_rows_reused > 0
            assert st.kv_bytes_saved > 0


def test_engine_prefix_parity_under_preemption(served):
    """A pool too small for the workload forces the optimistic policy to
    preempt mid-flight — including warm requests running on spliced
    shared pages. Preemption must decref (never free) shared pages and
    recompute must land on identical greedy tokens."""
    cfg, model, params = served
    rng = np.random.RandomState(44)
    prompts = _shared_prefix_prompts(cfg, rng, 4, prefix_len=20, page=8)

    reference, _ = _serve_prefix(model, params, prompts, prefix_cache=False,
                                 max_new=6)
    on, st = _serve_prefix(model, params, prompts, prefix_cache=True,
                           kv_pages=8, max_new=6)
    assert st.preemptions > 0, (
        "workload did not preempt — shrink kv_pages so the test exercises "
        "eviction of warm requests")
    assert st.prefix_hits > 0
    assert on == reference, "preempted warm request diverged on recompute"


def test_warm_prefix_smoke(served):
    """CI smoke (referenced by .github/workflows/ci.yml): a second wave of
    requests sharing the first wave's prompt prefix must hit the cache
    (hit rate > 0) and produce tokens identical to a cache-off engine."""
    cfg, model, params = served
    rng = np.random.RandomState(55)
    prompts = _shared_prefix_prompts(cfg, rng, 3, prefix_len=17, page=8)

    cold, _ = _serve_prefix(model, params, prompts, prefix_cache=False)
    warm, st = _serve_prefix(model, params, prompts, prefix_cache=True)
    assert warm == cold
    assert st.prefix_hit_rate > 0
    assert st.kv_bytes_saved > 0
    assert st.mean_ttft_warm_s > 0 and st.mean_ttft_cold_s > 0


def test_injected_preemption_of_warm_request_keeps_parity(served):
    """Deterministic fault injection preempts the latest-admitted resident
    — the warm request running on spliced shared pages. Its eviction must
    decref (never free) those pages and the requeue + recompute must land
    on the same greedy tokens as an undisturbed run."""
    from repro.serving import FaultConfig

    cfg, model, params = served
    rng = np.random.RandomState(66)
    # 3 requests through 2 slots: the first wave admits cold and
    # publishes; the third request admits warm on the shared prefix
    prompts = _shared_prefix_prompts(cfg, rng, 3, prefix_len=20, page=8)

    def serve(faults=None):
        engine = ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=64, kv_layout="paged", kv_page_size=8,
            prefix_cache=True, faults=faults))
        reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        return [list(map(int, r.generated)) for r in reqs], engine.stats()

    undisturbed, st0 = serve()
    assert st0.prefix_hits >= 1  # the late admission spliced the prefix
    chaotic, st = serve(FaultConfig(preempt_every=2))
    assert st.preemptions > 0
    assert st.prefix_hits >= 1
    assert chaotic == undisturbed


# ---------------------------------------------------------------------------
# Redesigned construction surface (ServingConfig / generate)
# ---------------------------------------------------------------------------


class TestServingAPI:
    def test_flat_kwargs_warn_but_work(self, served):
        cfg, model, params = served
        with pytest.warns(DeprecationWarning, match="ServingConfig"):
            e = ServingEngine(model, params, batch_slots=1, max_len=32)
        assert e.slots == 1

    def test_config_is_warning_free(self, served):
        cfg, model, params = served
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ServingEngine(model, params,
                          config=ServingConfig(batch_slots=1, max_len=32))

    def test_config_plus_kwargs_rejected(self, served):
        cfg, model, params = served
        with pytest.raises(ValueError, match="config"):
            ServingEngine(model, params,
                          config=ServingConfig(batch_slots=1, max_len=32),
                          batch_slots=2)

    def test_prefix_cache_requires_paged_layout(self):
        with pytest.raises(ValueError, match="prefix_cache"):
            ServingConfig(prefix_cache=True).validate()

    def test_from_args_round_trips_cli_flags(self):
        import argparse

        ap = argparse.ArgumentParser()
        ServingConfig.add_cli_args(ap)
        args = ap.parse_args(["--kv-layout", "paged", "--prefix-cache",
                              "--kv-page-size", "8", "--slots", "3",
                              "--prefix-cache-pages", "16"])
        config = ServingConfig.from_args(args, max_len=64)
        assert config.kv_layout == "paged" and config.prefix_cache
        assert config.kv_page_size == 8 and config.batch_slots == 3
        assert config.prefix_cache_pages == 16 and config.max_len == 64

    def test_generate_honors_sampling_budgets(self, served):
        cfg, model, params = served
        engine = ServingEngine(model, params, config=ServingConfig(
            batch_slots=1, max_len=32))
        prompt = np.arange(1, 6, dtype=np.int32)
        req = engine.generate(prompt, SamplingParams(max_new=3))
        assert len(req.generated) == 3 and req.done

    def test_sampling_params_validate_budgets(self):
        with pytest.raises(ValueError):
            SamplingParams(max_new=0)
        with pytest.raises(ValueError):
            SamplingParams(deadline_s=-1.0)
