"""Multi-device semantics tests. Each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps its single-device view (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    prelude = textwrap.dedent("""
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8, jax.devices()
    """)
    out = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pjit_fsdp_tp_matches_single_device():
    """The FSDP×TP-sharded train step (incl. ZeRO-3 weight-gather-at-use)
    must compute the same loss/params as the unsharded one (distribution
    changes layout, not math). Inputs are pre-placed with device_put:
    letting jit reshard at dispatch via in_shardings deadlocks XLA's CPU
    in-process communicator (runtime artifact, not a sharding bug — the
    same program executes fine pre-placed)."""
    res = run_sub("""
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel import (ParallelConfig, batch_pspecs,
                                    param_pspecs)
        from repro.training import (OptimizerConfig, init_opt_state,
                                    make_train_step)
        from repro.launch.mesh import make_local_mesh
        from repro.data import TokenStream

        cfg = get_config("llama3.2-1b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        stream = TokenStream(cfg.vocab_size, seq_len=16, global_batch=8, seed=0)
        batch = jax.tree.map(jnp.asarray, stream.batch(0))
        oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
        pc = ParallelConfig(remat="none")  # FSDP('data') x TP('model')

        step_ref = jax.jit(make_train_step(model, oc, pc))
        p_ref, _, m_ref = step_ref(params, init_opt_state(params), batch)

        mesh = make_local_mesh((4, 2), ("data", "model"))
        shard = lambda tree, spec: jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, spec, is_leaf=lambda x: hasattr(x, "shape"))
        params_sh = shard(params, param_pspecs(params, pc))
        opt = init_opt_state(params_sh)
        batch_sh = shard(batch, batch_pspecs(batch, pc))
        with mesh:
            p_sh, _, m_sh = jax.jit(make_train_step(model, oc, pc))(
                params_sh, opt, batch_sh)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                                  jax.tree_util.tree_leaves(p_sh))
                  if jnp.issubdtype(a.dtype, jnp.floating))
        print(json.dumps({"loss_ref": float(m_ref["loss"]),
                          "loss_sh": float(m_sh["loss"]), "err": err}))
    """)
    assert abs(res["loss_ref"] - res["loss_sh"]) < 1e-4
    assert res["err"] < 1e-3


@pytest.mark.xfail(strict=False, reason=(
    "known seed issue: the tiny llama config barely moves in 30 steps "
    "on this toolchain (DDP itself matches single-device bit-for-bit; "
    "tracked in ROADMAP open items)"))
def test_ddp_compressed_training_converges():
    """shard_map DDP with int8 EF compression: loss decreases and stays close
    to uncompressed DDP."""
    res = run_sub("""
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel import ParallelConfig
        from repro.training import OptimizerConfig, init_opt_state
        from repro.training.trainer import (init_ddp_error_state,
                                            make_ddp_train_step)
        from repro.launch.mesh import make_local_mesh
        from repro.data import TokenStream

        cfg = get_config("llama3.2-1b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        stream = TokenStream(cfg.vocab_size, seq_len=16, global_batch=8, seed=0)
        oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=2, total_steps=30,
                             weight_decay=0.0)
        pc = ParallelConfig(remat="none", moe_mode="dense")
        mesh = make_local_mesh((8,), ("data",))

        def run(compress):
            p = jax.tree.map(jnp.copy, params)
            opt = init_opt_state(p)
            err = init_ddp_error_state(p)
            step = make_ddp_train_step(model, oc, pc, mesh, "data",
                                       compress=compress)
            losses = []
            for i in range(30):
                batch = jax.tree.map(jnp.asarray, stream.batch(i))
                p, opt, err, m = step(p, opt, err, batch)
                losses.append(float(m["loss"]))
            return losses

        plain = run(False)
        comp = run(True)
        print(json.dumps({"plain_first": plain[0], "plain_last": sum(plain[-5:])/5,
                          "comp_first": comp[0], "comp_last": sum(comp[-5:])/5}))
    """)
    assert res["plain_last"] < res["plain_first"] - 0.3
    assert res["comp_last"] < res["comp_first"] - 0.3
    assert abs(res["comp_last"] - res["plain_last"]) < 0.5


def test_production_mesh_shapes():
    res = run_sub("""
        import numpy as np
        from repro.launch.mesh import make_local_mesh
        m = make_local_mesh((4, 2), ("data", "model"))
        print(json.dumps({"shape": [int(m.shape[a]) for a in ("data", "model")]}))
    """)
    assert res["shape"] == [4, 2]


def test_ep_sharding_lowers():
    """Expert-parallel MoE sharding compiles and matches the unsharded
    reference. Regression (seed): GSPMD sharded the ragged dispatch's
    group_sizes over 'model' and each expert shard misread its local slice
    as global cumulative row offsets (err ~5.0); routing now stays
    replicated and expert GEMMs run shard-local via shard_map."""
    res = run_sub("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel import ParallelConfig, param_pspecs
        from repro.launch.mesh import make_local_mesh

        cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        ref, _ = model.forward(params, tokens=toks, moe_mode="ragged")

        mesh = make_local_mesh((2, 4), ("data", "model"))
        pc = ParallelConfig(ep=True, moe_mode="ragged")
        pspec = param_pspecs(params, pc)
        sharded = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspec, is_leaf=lambda x: hasattr(x, "shape"))
        with mesh:
            out, _ = jax.jit(lambda p, t: model.forward(p, tokens=t,
                                                        moe_mode="ragged",
                                                        pc=pc))(sharded, toks)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-3


def test_ep_sharding_matches_for_merged_params():
    """EP-sharded output matches the unsharded reference for MERGED
    (group_map-routed) params too: the remap to merged slots happens in the
    replicated routing stage, so expert shards agree on slot ids. Also
    covers pad_expert_slots (merge to 6 slots, EP degree 4)."""
    res = run_sub("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.core import HCSMoEConfig, run_hcsmoe
        from repro.models import build_model
        from repro.parallel import (ParallelConfig, pad_expert_slots,
                                    param_pspecs)
        from repro.launch.mesh import make_local_mesh

        cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        key = jax.random.PRNGKey(3)
        calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                               (2, 32), 0, cfg.vocab_size)}
                 for i in range(2)]

        mesh = make_local_mesh((2, 4), ("data", "model"))
        pc = ParallelConfig(ep=True, moe_mode="ragged")
        errs = {}
        for target in (4, 6):  # 6 does not divide ep=4 -> padded slots
            merged, _ = run_hcsmoe(model, params, calib,
                                   HCSMoEConfig(target_experts=target))
            ref, _ = model.forward(merged, tokens=toks, moe_mode="ragged")
            padded = pad_expert_slots(merged, 4)
            pspec = param_pspecs(padded, pc)
            sharded = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                padded, pspec, is_leaf=lambda x: hasattr(x, "shape"))
            with mesh:
                out, _ = jax.jit(lambda p, t: model.forward(
                    p, tokens=t, moe_mode="ragged", pc=pc))(sharded, toks)
            errs[str(target)] = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps(errs))
    """)
    assert res["4"] < 1e-3
    assert res["6"] < 1e-3


def test_ep_serving_matches_single_device_engine():
    """End-to-end expert-parallel serving: a ServingEngine with an
    EP-sharded mesh (params placed per param_pspecs(ep=True), prefill/decode
    jitted with in/out shardings, spliced cache re-placed via device_put)
    generates exactly the same greedy tokens as the single-device engine,
    for both the original and the HC-SMoE-merged model — and each device
    holds only its expert slice."""
    res = run_sub("""
        from repro.configs import get_config
        from repro.core import HCSMoEConfig, run_hcsmoe
        from repro.models import build_model
        from repro.parallel import ParallelConfig
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import Request, ServingConfig, ServingEngine

        cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(3)
        calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                               (2, 32), 0, cfg.vocab_size)}
                 for i in range(2)]
        merged, _ = run_hcsmoe(model, params, calib,
                               HCSMoEConfig(target_experts=4))

        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (4, 7, 10, 5)]

        def serve(p, parallel=None, mesh=None):
            eng = ServingEngine(model, p, config=ServingConfig(
                batch_slots=2, max_len=32, parallel=parallel, mesh=mesh))
            reqs = [Request(uid=i, prompt=pr, max_new_tokens=4)
                    for i, pr in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            eng.run()
            return [r.generated for r in reqs], eng

        mesh = make_serving_mesh(8)
        pc = ParallelConfig(fsdp_axis=None, weight_gather=False, ep=True)
        out = {}
        for name, p in (("unmerged", params), ("merged", merged)):
            ref, _ = serve(p)
            ep, eng = serve(p, pc, mesh)
            eb = eng.expert_bytes_per_device()
            out[name] = {"match": ep == ref,
                         "bytes_ratio": eb["max_per_device"] / eb["total"]}
        print(json.dumps(out))
    """)
    for name in ("unmerged", "merged"):
        assert res[name]["match"], name
        # every device holds 1/8 of the (padded) expert stacks
        assert abs(res[name]["bytes_ratio"] - 1 / 8) < 1e-6, res[name]


def test_paged_ep_pallas_serving_matches_single_device_engine():
    """The tentpole composition: paged KV layout x Pallas flash-decode x
    expert-parallel mesh in ONE engine. The page pools shard over the model
    axis (head_dim for the reduced mixtral: K=2 does not divide tp=8 but
    hd=16 does), the paged flash-decode kernel runs per-shard inside
    shard_map's all-gather wrapper, and greedy tokens must be identical to
    the plain single-device contiguous/jnp engine. Per-device KV accounting
    must reflect the 8-way K/V split."""
    res = run_sub("""
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel import ParallelConfig
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import Request, ServingConfig, ServingEngine

        cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (4, 7, 10, 5)]

        def serve(**kw):
            eng = ServingEngine(model, params, config=ServingConfig(
                batch_slots=2, max_len=32, **kw))
            reqs = [Request(uid=i, prompt=pr, max_new_tokens=4)
                    for i, pr in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            eng.run()
            return [r.generated for r in reqs], eng

        ref, _ = serve()
        pc = ParallelConfig(fsdp_axis=None, weight_gather=False, ep=True)
        combo, eng = serve(kv_layout="paged", attn_impl="pallas",
                           parallel=pc, mesh=make_serving_mesh(8))
        st = eng.stats()
        km = eng.kv_memory()
        eb = eng.expert_bytes_per_device()
        print(json.dumps({
            "match": combo == ref,
            "kv_shards": st.kv_shard_degree,
            "peak": st.kv_bytes_peak,
            "peak_dev": st.kv_bytes_peak_per_device,
            "km_peak_dev": km["kv_bytes_peak_per_device"],
            "bytes_ratio": eb["max_per_device"] / eb["total"],
        }))
    """)
    assert res["match"]
    assert res["kv_shards"] == 8
    # K/V payload splits 8 ways; only the replicated kv_pos rows stay whole
    assert 0 < res["peak_dev"] < res["peak"]
    assert res["km_peak_dev"] == res["peak_dev"]
    assert abs(res["bytes_ratio"] - 1 / 8) < 1e-6
