"""Optional-``hypothesis`` shim for the property-based tests.

The tier-1 suite must collect and run on a bare container (no hypothesis).
When the package is present the real ``given``/``settings``/``st`` are
re-exported unchanged; when it is absent, ``@given(...)`` turns into a skip
marker and the strategy constructors return inert placeholders, so the
property tests skip cleanly while the plain-pytest invariant tests (which
cover the same core properties on fixed seeds) still run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)

        return deco

    class _Strategies:
        """Inert stand-ins for the strategy constructors the tests use."""

        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def floats(*_a, **_k):
            return None

        @staticmethod
        def sampled_from(*_a, **_k):
            return None

    st = _Strategies()

    class settings:  # noqa: N801 - mirrors hypothesis.settings
        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass
