"""compress CLI + serve --merge-plan end-to-end: the compress->serve smoke.

Covers the full artifact lifecycle through the real CLIs (compute ->
inspect -> apply -> serve) and pins the deployment contract: an engine
serving a SAVED plan generates token-for-token the same greedy output as an
engine running in-memory ``run_hcsmoe`` merging with the same calibration.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-m", *argv], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (argv, out.stderr[-3000:])
    return out.stdout


def test_compute_inspect_apply_serve_cli(tmp_path):
    plan_dir = str(tmp_path / "plan")
    out = _run(["repro.launch.compress", "compute", "--arch", "mixtral-8x7b",
                "--reduced", "--target", "4", "--calib-seqs", "4",
                "--calib-len", "32", "--out", plan_dir])
    assert "saved plan to" in out
    assert os.path.exists(os.path.join(plan_dir, "plan.json"))
    assert os.path.exists(os.path.join(plan_dir, "plan.npz"))

    out = _run(["repro.launch.compress", "inspect", plan_dir])
    assert "method=hc_smoe" in out
    assert "8 -> 4" in out
    assert "feat#" in out

    ckpt = str(tmp_path / "merged_ckpt")
    out = _run(["repro.launch.compress", "apply", "--arch", "mixtral-8x7b",
                "--reduced", plan_dir, "--out-checkpoint", ckpt])
    assert "saved merged checkpoint" in out

    out = _run(["repro.launch.serve", "--reduced", "--merge-plan", plan_dir,
                "--requests", "3", "--max-new", "6"])
    assert "serving hc_smoe plan" in out
    assert "served 3 requests" in out


def test_merge_to_and_merge_plan_are_mutually_exclusive(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--reduced",
         "--merge-to", "4", "--merge-plan", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode != 0
    assert "pick one" in (out.stderr + out.stdout)


@pytest.fixture(scope="module")
def plan_vs_inmemory():
    """Both serving setups built from identical seeds + calibration: one
    merges in-memory via run_hcsmoe, one applies a disk-round-tripped plan
    at engine load (ServingConfig.merge_plan)."""
    from repro.checkpoint import load_plan, save_plan
    from repro.configs import get_config
    from repro.core import (
        HCSMoEConfig, collect_moe_stats, compute_plan, run_hcsmoe)
    from repro.data import calibration_batches
    from repro.models import build_model

    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calibration_batches(cfg, n_seqs=4, seq_len=32, batch=4)
    merged_inmem, _ = run_hcsmoe(model, params, calib,
                                 HCSMoEConfig(target_experts=4))
    stats = collect_moe_stats(model, params, calib)
    plan = compute_plan(cfg, params, stats, HCSMoEConfig(target_experts=4))
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        save_plan(os.path.join(td, "plan"), plan)
        reloaded = load_plan(os.path.join(td, "plan"))
    return cfg, model, params, merged_inmem, reloaded


def test_served_plan_matches_in_memory_merge_token_for_token(
        plan_vs_inmemory):
    from repro.serving import Request, ServingConfig, ServingEngine

    cfg, model, params, merged_inmem, plan = plan_vs_inmemory

    def serve(engine):
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab_size, 12)
                        .astype(np.int32),
                        max_new_tokens=8) for i in range(4)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        return [r.generated for r in reqs]

    eng_inmem = ServingEngine(model, merged_inmem,
                              config=ServingConfig(batch_slots=2,
                                                   max_len=64))
    eng_plan = ServingEngine(model, params,
                             config=ServingConfig(batch_slots=2, max_len=64,
                                                  merge_plan=plan))
    assert serve(eng_inmem) == serve(eng_plan)
