"""Paged KV cache: page allocator invariants (plain + hypothesis property
tests), the page-table-aware flash-decode kernel vs its oracle, chunked
prefill vs monolithic prefill parity, and engine-level token identity
between the paged and contiguous layouts on both attention backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import build_model
from repro.models.kvcache import (
    PageAllocator, PageExhausted, contiguous_kv_bytes, init_paged_cache,
    paged_kv_page_bytes, supports_paging)
from repro.serving import Request, ServingConfig, ServingEngine
from tests._hyp import HAVE_HYPOTHESIS, given, settings, st

if HAVE_HYPOTHESIS:
    prop_settings = settings(max_examples=50, deadline=None)
else:  # decorators evaluate even under skipif; the shim settings is inert
    def prop_settings(f):
        return f


# ---------------------------------------------------------------------------
# PageAllocator (plain invariant tests)
# ---------------------------------------------------------------------------


class TestPageAllocator:
    def test_null_page_reserved(self):
        a = PageAllocator(num_pages=5, page_size=8)
        got = a.ensure(0, 4 * 8)
        assert sorted(got) == [1, 2, 3, 4]  # page 0 never handed out
        assert a.pages_free == 0

    def test_ensure_is_idempotent_and_incremental(self):
        a = PageAllocator(num_pages=9, page_size=8)
        first = a.ensure(0, 10)       # 2 pages
        assert len(first) == 2
        assert a.ensure(0, 16) == []  # already covered
        assert len(a.ensure(0, 17)) == 1
        assert a.owned(0)[:2] == first

    def test_release_round_trip_never_leaks(self):
        a = PageAllocator(num_pages=9, page_size=8)
        for _cycle in range(5):
            a.ensure(0, 24)
            a.ensure(1, 16)
            assert a.pages_in_use + a.pages_free == a.num_pages - 1
            a.release(0)
            a.release(1)
            assert a.pages_in_use == 0
            assert a.pages_free == a.num_pages - 1

    def test_no_double_assignment(self):
        a = PageAllocator(num_pages=17, page_size=4)
        a.ensure(0, 10)
        a.ensure(1, 20)
        a.ensure(2, 4)
        seen = set()
        for s in (0, 1, 2):
            for p in a.owned(s):
                assert p not in seen, f"page {p} owned twice"
                seen.add(p)

    def test_exhaustion_raises_and_leaves_state_untouched(self):
        a = PageAllocator(num_pages=4, page_size=8)
        a.ensure(0, 16)
        before = (a.pages_free, a.owned(0))
        with pytest.raises(PageExhausted, match="free"):
            a.ensure(1, 17)  # needs 3, only 1 free
        assert (a.pages_free, a.owned(0)) == before
        assert a.owned(1) == []

    def test_reserve_budgets_growth_without_allocating(self):
        a = PageAllocator(num_pages=6, page_size=8)
        a.reserve(0, 20)                 # 3 pages budgeted, none allocated
        assert a.pages_in_use == 0 and a.pages_free == 5
        assert a.pages_available == 2
        a.ensure(0, 9)                   # draws 2 of the 3 budgeted pages
        assert a.pages_available == 2    # unchanged: backed by ownership
        with pytest.raises(PageExhausted, match="budget"):
            a.reserve(1, 17)             # needs 3 > 2 available
        a.reserve(1, 16)                 # exactly fits
        assert a.pages_available == 0
        a.release(0)                     # frees pages AND the reservation
        assert a.pages_available == 3

    def test_fragmentation_heavy_reuse(self):
        """Interleaved admission/retirement cycles with mixed sizes: pages
        recycle through different slots without leak or overlap."""
        a = PageAllocator(num_pages=12, page_size=4)
        rng = np.random.RandomState(0)
        live = {}
        for _step in range(200):
            if live and (len(live) >= 3 or rng.rand() < 0.4):
                s = rng.choice(sorted(live))
                a.release(s)
                del live[s]
            else:
                s = int(rng.randint(0, 8))
                if s in live:
                    continue
                rows = int(rng.randint(1, 20))
                if a.pages_for(rows) <= a.pages_free:
                    a.ensure(s, rows)
                    live[s] = rows
            owned = [p for s in live for p in a.owned(s)]
            assert len(owned) == len(set(owned))
            assert 0 not in owned
            assert a.pages_in_use + a.pages_free == a.num_pages - 1


# ---------------------------------------------------------------------------
# PageAllocator (hypothesis property tests)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestAllocatorProperties:
    @prop_settings
    @given(st.integers(min_value=2, max_value=40),
           st.integers(min_value=1, max_value=16),
           st.integers(min_value=0, max_value=2 ** 60))
    def test_random_op_sequences_keep_invariants(self, num_pages, page_size,
                                                 seed):
        """Arbitrary ensure/release interleavings: no leak, no double
        assignment, exhaustion never corrupts, round-trips restore the
        free list exactly."""
        rng = np.random.RandomState(seed % (2 ** 32))
        a = PageAllocator(num_pages, page_size)
        live = set()
        for _ in range(60):
            op = rng.rand()
            s = int(rng.randint(0, 6))
            if op < 0.55:
                rows = int(rng.randint(1, 4 * page_size + 1))
                try:
                    fresh = a.ensure(s, rows)
                except PageExhausted:
                    assert a.pages_for(rows) - len(a.owned(s)) \
                        > a.pages_free
                else:
                    live.add(s)
                    assert len(a.owned(s)) >= a.pages_for(rows)
                    assert 0 not in fresh
            else:
                a.release(s)
                live.discard(s)
                assert a.owned(s) == []
            owned = [p for t in live for p in a.owned(t)]
            assert len(owned) == len(set(owned))
            assert a.pages_in_use + a.pages_free == a.num_pages - 1
        for t in sorted(live):
            a.release(t)
        assert a.pages_free == a.num_pages - 1

    @prop_settings
    @given(st.integers(min_value=1, max_value=200),
           st.integers(min_value=1, max_value=16))
    def test_pages_for_is_exact_ceiling(self, rows, page_size):
        a = PageAllocator(4, page_size)
        n = a.pages_for(rows)
        assert n * page_size >= rows
        assert (n - 1) * page_size < rows


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestExhaustionProperties:
    """PageExhausted error paths: every refusal is atomic (nothing recorded,
    nothing allocated) and reservations interact correctly with release —
    the invariants the engine's preemption loop leans on."""

    @prop_settings
    @given(st.integers(min_value=2, max_value=24),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=0, max_value=2 ** 60))
    def test_failed_reserve_is_atomic(self, num_pages, page_size, seed):
        """An over-budget reserve raises and records NOTHING: the slot's
        budget, every slot's pages, and pages_available are unchanged."""
        rng = np.random.RandomState(seed % (2 ** 32))
        a = PageAllocator(num_pages, page_size)
        # random pre-state: some owned pages, some reservations
        for s in range(3):
            rows = int(rng.randint(0, (num_pages // 2) * page_size + 1))
            try:
                a.ensure(s, rows)
                if rng.rand() < 0.5:
                    a.reserve(s, rows + int(rng.randint(0, page_size + 1)))
            except PageExhausted:
                pass
        snap = (a.pages_free, a.pages_available,
                {s: a.owned(s) for s in range(4)},
                {s: a.reserved(s) for s in range(4)})
        over = (max(a.pages_available, 0) + 1
                + int(rng.randint(0, 3))) * page_size
        with pytest.raises(PageExhausted):
            a.reserve(3, over)
        assert (a.pages_free, a.pages_available,
                {s: a.owned(s) for s in range(4)},
                {s: a.reserved(s) for s in range(4)}) == snap

    @prop_settings
    @given(st.integers(min_value=2, max_value=24),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=0, max_value=2 ** 60))
    def test_failed_ensure_on_drained_pool_is_atomic(self, num_pages,
                                                     page_size, seed):
        """ensure on an exhausted (or insufficient) pool raises with the
        requesting slot untouched, and a release afterwards makes the
        identical request succeed — the preempt-retry cycle."""
        rng = np.random.RandomState(seed % (2 ** 32))
        a = PageAllocator(num_pages, page_size)
        a.ensure(0, (num_pages - 1) * page_size)     # drain the free list
        assert a.pages_free == 0
        # a demand the pool CAN satisfy once the victim is gone
        rows = int(rng.randint(1, (num_pages - 1) * page_size + 1))
        with pytest.raises(PageExhausted):
            a.ensure(1, rows)
        assert a.owned(1) == [] and a.reserved(1) == 0
        a.release(0)                                 # "preempt the victim"
        assert len(a.ensure(1, rows)) == a.pages_for(rows)

    @prop_settings
    @given(st.integers(min_value=3, max_value=24),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=0, max_value=2 ** 60))
    def test_release_while_reserved_returns_full_budget(self, num_pages,
                                                        page_size, seed):
        """release on a slot holding BOTH pages and a reservation drops
        both, so pages_available rebounds by the whole budget — never a
        partial refund that would strand headroom forever."""
        rng = np.random.RandomState(seed % (2 ** 32))
        a = PageAllocator(num_pages, page_size)
        budget_pages = int(rng.randint(1, num_pages))
        a.reserve(0, budget_pages * page_size)
        drawn = int(rng.randint(0, budget_pages + 1))
        if drawn:
            a.ensure(0, drawn * page_size)
        assert a.pages_available == num_pages - 1 - budget_pages
        a.release(0)
        assert a.reserved(0) == 0 and a.owned(0) == []
        assert a.pages_available == num_pages - 1
        assert a.pages_free == num_pages - 1

    @prop_settings
    @given(st.integers(min_value=2, max_value=20),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=0, max_value=2 ** 60))
    def test_reserved_growth_never_fails(self, num_pages, page_size, seed):
        """The reserve-policy contract: once reserve(slot, n) succeeds,
        any ensure(slot, m <= n) succeeds regardless of other slots'
        reserve pressure on the remaining pool."""
        rng = np.random.RandomState(seed % (2 ** 32))
        a = PageAllocator(num_pages, page_size)
        budget = int(rng.randint(1, num_pages)) * page_size
        a.reserve(0, budget)
        # competing slots soak up everything else (reserve may refuse)
        for s in range(1, 4):
            try:
                a.reserve(s, int(rng.randint(1, num_pages)) * page_size)
            except PageExhausted:
                pass
        rows = 0
        while rows < budget:
            rows = min(rows + int(rng.randint(1, page_size + 1)), budget)
            a.ensure(0, rows)   # must never raise
        assert len(a.owned(0)) == a.pages_for(budget)


# ---------------------------------------------------------------------------
# Paged flash-decode kernel vs oracle
# ---------------------------------------------------------------------------


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-3, atol=1e-3)


def _paged_case(B, page, P, K, G, hd, pos_vals, dtype=jnp.float32, seed=0,
                shuffle=True):
    """Random pools + a SCATTERED page table (physical ids shuffled across
    slots, page 0 kept null) with the engine invariant: slot b has pages
    covering rows 0..pos_b and kv_pos[row] == row."""
    H = K * G
    N = B * P + 1
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, H, hd), dtype)
    kp = jax.random.normal(jax.random.fold_in(key, 1), (N, page, K, hd),
                           dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 2), (N, page, K, hd),
                           dtype)
    rng = np.random.RandomState(seed)
    phys = rng.permutation(np.arange(1, N)) if shuffle else np.arange(1, N)
    table = np.zeros((B, P), np.int32)
    kv_pos = np.full((N, page), -1, np.int32)
    nxt = 0
    for b, pos in enumerate(pos_vals):
        n_pages = pos // page + 1
        table[b, :n_pages] = phys[nxt:nxt + n_pages]
        nxt += n_pages
        rows = np.arange(pos + 1)
        kv_pos.reshape(-1)[table[b, rows // page] * page + rows % page] = rows
    return (q, kp, vp, jnp.asarray(kv_pos), jnp.asarray(table),
            jnp.asarray(np.asarray(pos_vals, np.int32)))


def _check_paged(*case, **kw):
    q = case[0]
    o = ops.flash_decode_paged(*case, **kw)
    o_ref = ref.flash_decode_paged_ref(*case, **kw)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(q.dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G", [1, 4, 8])
def test_paged_kernel_gqa_ratios(G, dtype):
    _check_paged(*_paged_case(2, 16, 4, 2, G, 32, [5, 63], dtype=dtype))


def test_paged_kernel_partial_pages_skip():
    """Slots resident on a fraction of their pages: tiles past the filled
    prefix are skipped and unallocated table entries (null page) masked."""
    _check_paged(*_paged_case(3, 8, 8, 2, 4, 32, [0, 3, 60]))


@pytest.mark.parametrize("window", [8, 16])
def test_paged_kernel_sliding_window(window):
    """Window masking plus the paged-only LOWER tile skip: pages wholly
    before pos-window hold only masked rows."""
    _check_paged(*_paged_case(2, 8, 8, 1, 4, 16, [7, 60]), window=window)


def test_paged_kernel_window_page_boundary():
    """Regression: when (pos - window) % page == page - 1, the lower-skip
    gate used to run the first DEAD tile while the clamped index map
    redirected its DMA onto the first live page, double-counting that page
    in the online softmax. Sweep pos across a full page period so every
    boundary phase (including the off-by-one trigger, e.g. pos=23 with
    window 16 / page 8) is covered."""
    for pos in range(16, 40):
        _check_paged(*_paged_case(1, 8, 8, 2, 2, 16, [pos], seed=pos),
                     window=16)


def test_paged_kernel_softcap_and_window_fused():
    _check_paged(*_paged_case(2, 8, 4, 2, 2, 16, [10, 30]), window=16,
                 logit_cap=50.0)


def test_paged_kernel_custom_scale():
    _check_paged(*_paged_case(1, 16, 2, 2, 2, 16, [31]), scale=0.25)


def test_paged_matches_contiguous_flash_decode():
    """With an identity page layout, the paged kernel must agree with the
    contiguous PR-3 kernel on the same logical cache."""
    q, kp, vp, kv_pos, table, pos = _paged_case(
        2, 16, 4, 2, 4, 32, [20, 55], shuffle=False)
    from repro.models.kvcache import gather_paged_kv

    k = gather_paged_kv(kp, table)
    v = gather_paged_kv(vp, table)
    kvp = gather_paged_kv(kv_pos, table)
    o_paged = ops.flash_decode_paged(q, kp, vp, kv_pos, table, pos)
    o_contig = ops.flash_decode(q, k, v, kvp, pos)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_contig),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Chunked extend vs monolithic prefill (model level)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "gemma2-2b"])
def test_chunked_extend_matches_monolithic_prefill(arch):
    """Driving model.extend chunk-by-chunk over a paged cache must
    reproduce the monolithic prefill's last-token logits within dtype
    tolerance (the chunked-prefill acceptance criterion), including ragged
    tail chunks neutralised by the valid mask."""
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (37,), 0, cfg.vocab_size), np.int32)
    lp_ref, _ = model.prefill(
        params, tokens=jnp.asarray(prompt[None]), cache_max_len=64,
        last_pos=jnp.asarray([len(prompt) - 1], jnp.int32))

    page, C = 8, 8
    alloc = PageAllocator(num_pages=9, page_size=page)
    cache = init_paged_cache(cfg, 1, 64, num_pages=9, page_size=page,
                             dtype=jnp.float32)
    lp = None
    for off in range(0, len(prompt), C):
        take = min(C, len(prompt) - off)
        alloc.ensure(0, off + take)
        cache["page_table"] = jnp.asarray(alloc.table_row(0, 8)[None])
        toks = np.zeros((1, C), np.int32)
        toks[0, :take] = prompt[off:off + take]
        lp, cache = model.extend(params, tokens=jnp.asarray(toks),
                                 cache=cache,
                                 valid=jnp.asarray([take], jnp.int32))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_ref),
                               rtol=1e-4, atol=1e-4)
    assert int(cache["pos"][0]) == len(prompt)


def test_extend_valid_zero_freezes_slot():
    """valid=0 must leave a slot's pos, pages, and kv_pos untouched (how
    decode freezes still-prefilling slots and dead slots)."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = init_paged_cache(cfg, 2, 32, num_pages=9, page_size=8,
                             dtype=jnp.float32)
    alloc = PageAllocator(9, 8)
    alloc.ensure(0, 8)
    table = np.stack([alloc.table_row(s, 4) for s in range(2)])
    cache["page_table"] = jnp.asarray(table)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0,
                                         cfg.vocab_size), np.int32)
    _, cache = model.extend(params, tokens=jnp.asarray(toks), cache=cache,
                            valid=jnp.asarray([4, 0], jnp.int32))
    assert cache["pos"].tolist() == [4, 0]
    kvp = np.asarray(cache["kv_pos"])
    assert (kvp[table[0, 0]][:4] == np.arange(4)).all()
    # slot 1 owns nothing; only the null page may have been touched, and
    # only with the -1 sentinel
    assert (kvp[1:] == -1).sum() + 4 == (kvp[1:]).size
    assert (kvp[0] == -1).all()


def test_decode_step_rejects_paged_cache():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = init_paged_cache(cfg, 1, 32, num_pages=5, page_size=8,
                             dtype=jnp.float32)
    with pytest.raises(ValueError, match="extend"):
        model.decode_step(params, tokens=jnp.zeros((1, 1), jnp.int32),
                          cache=cache)


# ---------------------------------------------------------------------------
# Engine: paged vs contiguous token identity, chunking, gating, telemetry
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paged_served():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run_engine(model, params, prompts, max_new=5, **kw):
    engine = ServingEngine(model, params, config=ServingConfig(**kw))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs], engine


@pytest.mark.parametrize("arch,impl", [
    ("mixtral-8x7b", "jnp"), ("mixtral-8x7b", "pallas"),
    ("gemma2-2b", "jnp"), ("gemma2-2b", "pallas"),
])
def test_paged_engine_token_identical_to_contiguous(arch, impl):
    """The tentpole acceptance criterion: greedy serving is token-identical
    between kv_layout='paged' and the contiguous PR-3 path, per backend, on
    mixtral (plain GQA) and gemma2 (sliding window + softcap, prompts past
    the window so the contiguous ring actually wraps), including slot reuse
    through the queue."""
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 20, 7, 26, 11)]  # > window=16 rows wrap
    kw = dict(batch_slots=2, max_len=64, attn_impl=impl, max_new=6)
    base, _ = _run_engine(model, params, prompts, **kw)
    paged, engine = _run_engine(model, params, prompts,
                                kv_layout="paged", kv_page_size=8, **kw)
    assert base == paged
    st = engine.stats()
    assert st.kv_pages_total > 0 and st.kv_pages_peak > 0
    assert st.kv_pages_in_use == 0  # everything released on retirement
    assert st.kv_bytes_peak < st.kv_bytes_contiguous


def test_chunked_prefill_token_identical(paged_served):
    """Chunked prefill (long prompts interleaved with decode) must not
    change any request's tokens vs monolithic paged prefill, and its
    telemetry must account chunks exactly once."""
    cfg, model, params = paged_served
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 40, 6, 33)]  # queueing forces slot reuse
    kw = dict(batch_slots=3, max_len=64, kv_layout="paged", kv_page_size=8)
    mono, _ = _run_engine(model, params, prompts, **kw)
    chunked, engine = _run_engine(model, params, prompts,
                                  prefill_chunk=8, **kw)
    assert mono == chunked
    st = engine.stats()
    # 40 -> 5 chunks, 33 -> 5 chunks; batching may overlap them but every
    # chunk dispatch is counted once
    assert 5 <= st.prefill_chunk_calls <= 10
    assert st.prefill_calls > 0          # shorts still take the bucket path
    long_req = [r for r in engine.finished if len(r.prompt) == 40][0]
    assert long_req.prefill_time > 0
    # accrued per chunk, not overwritten by the last call: strictly more
    # than any single dispatch could account for is hard to assert on CPU
    # noise, but the wall-clock must at least be a sum over >1 chunk
    assert st.mean_prefill_s > 0


def test_chunked_prefill_no_mega_bucket(paged_served):
    """Long prompts must NOT compile power-of-two mega-buckets: with
    chunking on, the only compiled prefill shapes are short buckets."""
    cfg, model, params = paged_served
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 56, 44)]
    _, engine = _run_engine(model, params, prompts, batch_slots=2,
                            max_len=64, kv_layout="paged", kv_page_size=8,
                            prefill_chunk=8)
    assert all(L <= 8 for _, L in engine.prefill_shapes), \
        engine.prefill_shapes


def test_paged_pool_backpressure(paged_served):
    """A pool smaller than the worst case serves a queue by waiting for
    retirements to free pages — and never deadlocks on a pool that can
    hold at least one request."""
    cfg, model, params = paged_served
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(4)]
    # 12 + 4 new tokens = 2 pages of 8 per request; pool of 3 allocatable
    # pages fits ONE resident request at a time
    toks, engine = _run_engine(model, params, prompts, max_new=4,
                               batch_slots=2, max_len=32,
                               kv_layout="paged", kv_page_size=8,
                               kv_pages=4)
    assert all(len(t) == 4 for t in toks)
    assert engine.stats().kv_pages_peak <= 3

    with pytest.raises(RuntimeError, match="kv_pages"):
        # a single request that can NEVER fit must raise, not spin
        _run_engine(model, params, [prompts[0]], max_new=4,
                    batch_slots=2, max_len=32, kv_layout="paged",
                    kv_page_size=8, kv_pages=2)


def test_paged_admission_reserves_decode_growth(paged_served):
    """Regression: admission used to budget only the PROMPT's pages, so a
    16-token prompt admitted into a near-full pool crashed with
    PageExhausted on the first decode step that crossed a page boundary
    (row 16 -> page 3). Worst-case (prompt + max_new) reservation must
    instead defer the second request until the first retires."""
    cfg, model, params = paged_served
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(2)]
    # 16 + 4 rows -> 3 pages of 8 per request; 4 usable pages hold ONE
    toks, engine = _run_engine(model, params, prompts, max_new=4,
                               batch_slots=2, max_len=32,
                               kv_layout="paged", kv_page_size=8,
                               kv_pages=5)
    assert all(len(t) == 4 for t in toks)
    assert engine.stats().kv_pages_peak <= 4


def test_paged_gating():
    """Clear errors: paged+recurrent mixers, chunking without paging, bad
    layout name. paged+EP is LEGAL since the serving runtime unification
    (the composition matrix in tests/test_serving.py covers it serving
    token-identically); only genuinely impossible combos raise."""
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    with pytest.raises(ValueError, match="paged"):
        ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=32, prefill_chunk=8))
    with pytest.raises(ValueError, match="kv_layout"):
        ServingEngine(model, params, config=ServingConfig(
            batch_slots=2, max_len=32, kv_layout="ring"))

    ssm = get_config("jamba-v0.1-52b").reduced(dtype="float32")
    assert not supports_paging(ssm)
    ssm_model = build_model(ssm)
    ssm_params = ssm_model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-family"):
        ServingEngine(ssm_model, ssm_params, config=ServingConfig(
            batch_slots=2, max_len=32, kv_layout="paged"))


def test_kv_accounting_helpers():
    cfg = get_config("mixtral-8x7b").reduced(dtype="float32")
    page_b = paged_kv_page_bytes(cfg, 8)
    contig = contiguous_kv_bytes(cfg, 4, 64)
    # full-window arch: 4 slots x 64 rows == 32 pages of 8 rows, so fully
    # paging the worst case costs exactly the contiguous provisioning
    assert page_b * 32 == contig
    # gemma2's local layers keep 16-row contiguous rings, so its contiguous
    # provisioning is below the every-layer-full-window figure
    g = get_config("gemma2-2b").reduced(dtype="float32")
    assert g.sliding_window == 16
    full = dataclasses.replace(g, sliding_window=0)
    assert contiguous_kv_bytes(g, 1, 64) < contiguous_kv_bytes(full, 1, 64)
