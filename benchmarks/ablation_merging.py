"""Table 7 analog: merging strategy (frequency/average/fix-dom) under fixed
HC-average-linkage expert-output clusters. Expectation (paper): differences
are marginal once clusters are good."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    rows = []
    for frac, label in [(0.75, "25%"), (0.5, "50%")]:
        r = max(1, int(round(cfg.moe.num_experts * frac)))
        for merge in ["frequency", "average", "fix_dom"]:
            hc = HCSMoEConfig(target_experts=r, merge=merge)
            merged, us = timed(lambda: apply_hcsmoe(cfg, params, stats, hc)[0])
            row = {"merge": merge, "reduction": label,
                   **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"merging/{label}/{merge}", us, row["Average"])
    record("table7_merging_methods", rows)
    return rows
