"""Generate the EXPERIMENTS.md roofline/dry-run tables from results JSONs.

  PYTHONPATH=src python -m benchmarks.report > results/roofline_table.md
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(directory=DRYRUN, tagged=False):
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        tag = parts[3] if len(parts) > 3 else ""
        if bool(tag) != tagged:
            continue
        with open(path) as f:
            d = json.load(f)
        d["tag"] = d.get("tag") or tag
        rows.append(d)
    return rows


def fmt_row(d):
    if d["status"] == "skipped":
        return (d["arch"], d["shape"], d["mesh"], "skip", "-", "-", "-", "-",
                "-", "-", "-")
    if d["status"] != "ok":
        return (d["arch"], d["shape"], d["mesh"], "ERROR", "-", "-", "-", "-",
                "-", "-", "-")
    r = d["roofline"]
    m = d["model_flops"]
    mem = d["memory"].get("total_bytes_per_device", 0) / 2**30
    frac = r["compute_s"] / max(r["step_time_lower_bound_s"], 1e-12)
    return (d["arch"], d["shape"], d["mesh"],
            r["bottleneck"].replace("_s", ""),
            f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
            f"{r['collective_s']:.3f}", f"{r['step_time_lower_bound_s']:.3f}",
            f"{mem:.1f}", f"{m['useful_ratio']:.3f}", f"{frac:.3f}")


HEADER = ("| arch | shape | mesh | bottleneck | compute_s | memory_s | "
          "collective_s | step_lb_s | HBM GiB/dev | useful-FLOPs | "
          "roofline-frac |")
SEP = "|" + "---|" * 11


def table(rows):
    out = [HEADER, SEP]
    for d in rows:
        out.append("| " + " | ".join(fmt_row(d)) + " |")
    return "\n".join(out)


def main():
    print("## Baseline roofline table (single-pod 16x16 + multi-pod 2x16x16)")
    print()
    print(table(load()))
    print()
    tagged = load(tagged=True)
    if tagged:
        print("## Tagged perf-iteration cells")
        print()
        print(HEADER.replace("| arch |", "| arch (tag) |"))
        print(SEP)
        for d in tagged:
            row = list(fmt_row(d))
            row[0] = f"{d['arch']} ({d.get('tag', '')})"
            print("| " + " | ".join(row) + " |")


if __name__ == "__main__":
    main()
