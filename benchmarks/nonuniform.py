"""Table 8 analog: non-uniform (frequency-allocated) per-layer cluster counts
vs uniform HC-SMoE at 25% reduction."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    r = max(1, int(round(cfg.moe.num_experts * 0.75)))
    rows = []
    for linkage in ["single", "average"]:
        for metric in ["weight", "expert_output"]:
            for merge in ["frequency", "fix_dom"]:
                hc = HCSMoEConfig(target_experts=r, linkage=linkage,
                                  metric=metric, merge=merge,
                                  non_uniform=True, resize=False)
                merged, us = timed(
                    lambda: apply_hcsmoe(cfg, params, stats, hc)[0])
                row = {"linkage": linkage, "metric": metric, "merge": merge,
                       **ctx.eval_model(merged)}
                rows.append(row)
                emit_csv(f"nonuniform/{linkage}/{metric}/{merge}", us,
                         row["Average"])
    record("table8_nonuniform", rows)
    return rows
