"""Tables 2-3 analog: HC-SMoE vs all retraining-free baselines at 25% and
50% expert reduction, per-task eval loss (lower better)."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe
from repro.core import baselines as bl

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, model, params = ctx.cfg, ctx.model, ctx.params
    stats = ctx.stats()
    E = cfg.moe.num_experts
    rows = [{"method": "None (original)", "r": E,
             **ctx.eval_model(params), "time_us": 0.0}]

    for frac, label in [(0.75, "25%"), (0.5, "50%")]:
        r = max(1, int(round(E * frac)))
        variants = [
            ("O-prune", lambda: bl.o_prune(cfg, params, stats, r, samples=24)[0]),
            ("F-prune", lambda: bl.f_prune(cfg, params, stats, r)[0]),
            ("S-prune", lambda: bl.s_prune(cfg, params, stats, r)[0]),
            ("M-SMoE", lambda: bl.m_smoe(cfg, params, stats, r)[0]),
            ("HC-SMoE (avg)", lambda: apply_hcsmoe(
                cfg, params, stats, HCSMoEConfig(target_experts=r))[0]),
            ("HC-SMoE (single)", lambda: apply_hcsmoe(
                cfg, params, stats,
                HCSMoEConfig(target_experts=r, linkage="single"))[0]),
        ]
        for name, fn in variants:
            merged, us = timed(fn)
            row = {"method": name, "r": r, "reduction": label,
                   **ctx.eval_model(merged), "time_us": us}
            rows.append(row)
            emit_csv(f"quality_main/{label}/{name}", us, row["Average"])

    record("table2_3_quality_main", rows)
    return rows
