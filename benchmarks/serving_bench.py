"""Serving throughput benchmark: merged vs. unmerged continuous batching.

The paper's deployment claim (Table 20) is that HC-SMoE-merged experts serve
unchanged — fewer expert weights, same engine. This table measures it the
way a serving team would: a mixed-prompt-length request workload driven
through :class:`ServingEngine`, reporting aggregate decode tokens/s and mean
time-to-first-token for the original and the merged model, across the
``ragged`` / ``capacity`` / ``pallas`` MoE compute paths.

Emits ``serving/<model>/<mode>`` rows (us_per_call = us per generated token;
derived = ``tok_s=..;ttft_ms=..;prefill_compiles=..``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit_csv, record

MOE_MODES = ("ragged", "capacity", "pallas")


def _workload(cfg, *, n_requests, max_new, seed=0):
    rng = np.random.RandomState(seed)
    lens = rng.choice([4, 6, 8, 12, 16, 24], size=n_requests)
    from repro.serving import Request

    return [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size, int(n))
                    .astype(np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lens)]


def _serve_once(model, params, cfg, moe_mode, *, n_requests, max_new,
                slots=4, max_len=64):
    from repro.serving import ServingEngine

    engine = ServingEngine(model, params, batch_slots=slots, max_len=max_len,
                           moe_mode=moe_mode)
    # warm-up with the IDENTICAL workload so every prefill bucket shape the
    # timed window will hit is already compiled (same seed -> same prompt
    # lengths -> same admission groupings)
    for r in _workload(cfg, n_requests=n_requests, max_new=max_new):
        engine.submit(r)
    engine.run()
    engine.reset_stats()

    for r in _workload(cfg, n_requests=n_requests, max_new=max_new):
        engine.submit(r)
    engine.run()
    return engine.stats()


def run(ctx):
    model, cfg = ctx.model, ctx.cfg
    params = ctx.params
    from repro.core import HCSMoEConfig, apply_hcsmoe

    merged, _ = apply_hcsmoe(
        cfg, params, ctx.stats(),
        HCSMoEConfig(target_experts=max(2, cfg.moe.num_experts // 2)))

    n_requests = 4 if ctx.fast else 8
    max_new = 4 if ctx.fast else 8
    rows = []
    for mode in MOE_MODES:
        for name, p in (("unmerged", params), ("merged", merged)):
            st = _serve_once(model, p, cfg, mode,
                             n_requests=n_requests, max_new=max_new)
            us_per_tok = (st.wall_time_s * 1e6 / st.total_new_tokens
                          if st.total_new_tokens else float("inf"))
            derived = (f"tok_s={st.tokens_per_s:.1f};"
                       f"ttft_ms={st.mean_ttft_s * 1e3:.1f};"
                       f"prefill_compiles={st.prefill_compilations}")
            emit_csv(f"serving/{name}/{mode}", us_per_tok, derived)
            rows.append({"model": name, "moe_mode": mode,
                         "tokens_per_s": st.tokens_per_s,
                         "mean_ttft_s": st.mean_ttft_s,
                         "mean_queue_s": st.mean_queue_s,
                         "mean_prefill_s": st.mean_prefill_s,
                         "total_new_tokens": st.total_new_tokens,
                         "requests": st.requests,
                         "prefill_compilations": st.prefill_compilations,
                         "decode_steps": st.decode_steps})
    record("serving", rows)
