"""Serving throughput benchmark: merged vs. unmerged continuous batching,
jnp vs. pallas attention backends.

The paper's deployment claim (Table 20) is that HC-SMoE-merged experts serve
unchanged — fewer expert weights, same engine. This table measures it the
way a serving team would: a mixed-prompt-length request workload driven
through :class:`ServingEngine`, reporting aggregate decode tokens/s, mean
time-to-first-token, and per-step decode latency for the original and the
merged model, across the ``ragged`` / ``capacity`` / ``pallas`` MoE compute
paths x the ``jnp`` / ``pallas`` attention backends (flash-decode kernel on
the decode hot path).

Emits ``serving/<model>/<mode>/<attn_impl>`` rows (us_per_call = us per
generated token; derived = ``tok_s=..;ttft_ms=..;decode_ms=..``) and writes
``results/BENCH_serving.json`` (schema: moe path x attn impl x merged ->
tokens/s, TTFT, decode step ms) so future PRs can regress-check the perf
trajectory — CI enforces it via ``benchmarks/check_regression.py`` (see
benchmarks/README.md for the re-baselining contract).

A second table drives a MIXED short/long prompt workload through four KV
configurations — contiguous, paged, paged+chunked-prefill, and the fully
composed ``paged_pallas_ep`` (paged pool x flash-decode kernels x an
expert-parallel serving mesh, trivial 1-device on the gated CPU run) —
reporting the KV bytes actually resident (page-pool peak) vs contiguous
provisioning, plus the TTFT and decode-stall (longest single engine step)
deltas that chunked prefill buys the co-tenants of a long prompt. The
composed row is asserted greedy-token-identical to the contiguous/jnp
engine before it is recorded.

A third table oversubscribes the page pool (aggregate worst-case demand
well above the physical pages) and serves it under both paged admission
policies — ``optimistic`` (admit on expected occupancy, preempt + recompute
on exhaustion) vs ``reserve`` (worst-case budgeting, never preempts) —
reporting preemption counts, mean requeue wait, and KV-page utilization,
with greedy tokens asserted identical to an ample-pool reference for both
(``overload`` key in the JSON; semantics in docs/serving_lifecycle.md).

A fourth table (``speculative`` key) serves the same workload with
speculative decoding on: MergePlan-merged copies of the target at two
compression ratios act as zero-training draft models, and the table
reports acceptance rate, tokens emitted per verify dispatch (the
per-stream decode-step speedup), and the target-dispatch reduction vs the
sequential engine — with greedy-token parity asserted first, since the
seeded-acceptance rule makes speculation a pure scheduling change.

On a no-TPU box the pallas backend runs in interpret mode —
wall-clock there measures the interpreter, not the kernel — so the JSON
also carries the analytic per-step FLOP/byte accounting
(:func:`repro.kernels.flash_decode.decode_attn_accounting`) that quantifies
the split-KV + length-aware-skip savings hardware-independently.

Standalone expert-parallel mode::

  PYTHONPATH=src python benchmarks/serving_bench.py --ep [--fast]

runs the merged and unmerged models under an expert-sharded
(data=1, model=N) mesh and reports, next to throughput, the PER-DEVICE
expert-parameter bytes — the paper's memory-saving claim measured where it
matters for deployment, per chip. Forces an 8-way host-platform device view
when run on a single-device box (so jax must not be imported before
``main()`` parses flags). The EP table also serves the combined
paged + pallas + EP engine (page pools sharded over the model axis, the
flash kernels launched per-shard via repro.kernels.partition) and asserts
its greedy tokens match the single-device jnp engine before reporting
per-device KV bytes next to the expert bytes.
"""
from __future__ import annotations

import json
import os

import numpy as np

MOE_MODES = ("ragged", "capacity", "pallas")
ATTN_IMPLS = ("jnp", "pallas")
WORKLOAD_LENS = (4, 6, 8, 12, 16, 24)
JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                         "BENCH_serving.json")


def _workload(cfg, *, n_requests, max_new, seed=0):
    rng = np.random.RandomState(seed)
    lens = rng.choice(WORKLOAD_LENS, size=n_requests)
    from repro.serving import Request

    return [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size, int(n))
                    .astype(np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lens)]


REPEATS = 3  # timed repetitions per row; the BEST one is recorded


def _serve_once(model, params, cfg, moe_mode, *, n_requests, max_new,
                slots=4, max_len=64, attn_impl="jnp", kv_layout="contiguous",
                parallel=None, mesh=None, repeats=REPEATS):
    from repro.serving import ServingConfig, ServingEngine

    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=slots, max_len=max_len, moe_mode=moe_mode,
        attn_impl=attn_impl, kv_layout=kv_layout, parallel=parallel,
        mesh=mesh))
    # warm-up with the IDENTICAL workload so every prefill bucket shape the
    # timed window will hit is already compiled (same seed -> same prompt
    # lengths -> same admission groupings); then record the BEST of
    # `repeats` timed repetitions — single CPU-runner samples swing by
    # multiples on a noisy box, and the regression gate needs a floor,
    # not a lottery ticket
    for r in _workload(cfg, n_requests=n_requests, max_new=max_new):
        engine.submit(r)
    engine.run()

    best = None
    for _ in range(repeats):
        engine.reset_stats()
        for r in _workload(cfg, n_requests=n_requests, max_new=max_new):
            engine.submit(r)
        engine.run()
        st = engine.stats()
        if best is None or st.tokens_per_s > best.tokens_per_s:
            best = st
    return best, engine


def _mixed_workload(cfg, *, n_short, n_long, long_len, max_new, seed=0):
    rng = np.random.RandomState(seed)
    from repro.serving import Request

    lens = list(rng.choice(WORKLOAD_LENS, size=n_short)) + [long_len] * n_long
    rng.shuffle(lens)
    return [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size, int(n))
                    .astype(np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lens)]


def _serve_paged_config(model, cfg, params, *, label, engine_kw, n_short,
                        n_long, long_len, max_new, slots, max_len):
    from repro.serving import ServingConfig, ServingEngine

    engine = ServingEngine(model, params, config=ServingConfig(
        batch_slots=slots, max_len=max_len, **engine_kw))
    wl = dict(n_short=n_short, n_long=n_long, long_len=long_len,
              max_new=max_new)
    for r in _mixed_workload(cfg, **wl):     # warm-up: compile every shape
        engine.submit(r)
    engine.run()
    # best-of-N timed repetitions, like _serve_once (gate needs a floor)
    st = best_finished = None
    for _ in range(REPEATS):
        engine.reset_stats()
        for r in _mixed_workload(cfg, **wl):
            engine.submit(r)
        engine.run()
        rep = engine.stats()
        if st is None or rep.tokens_per_s > st.tokens_per_s:
            st, best_finished = rep, list(engine.finished)
    mem = engine.kv_memory()
    short_ttft = [r.ttft for r in best_finished
                  if len(r.prompt) < long_len]
    long_ttft = [r.ttft for r in best_finished
                 if len(r.prompt) >= long_len]
    row = {
        "config": label,
        "tokens_per_s": st.tokens_per_s,
        "mean_ttft_s": st.mean_ttft_s,
        "short_ttft_s": float(np.mean(short_ttft)) if short_ttft else 0.0,
        "long_ttft_s": float(np.mean(long_ttft)) if long_ttft else 0.0,
        "decode_step_ms": st.decode_step_ms,
        "max_step_s": st.max_step_s,
        "prefill_chunk_calls": st.prefill_chunk_calls,
        "prefill_compilations": st.prefill_compilations,
        "kv_pages_peak": st.kv_pages_peak,
        "kv_pages_total": st.kv_pages_total,
        "kv_page_util": st.kv_page_util,
        "kv_bytes_peak": st.kv_bytes_peak,
        "kv_shard_degree": st.kv_shard_degree,
        "kv_bytes_peak_per_device": st.kv_bytes_peak_per_device,
        "kv_bytes_provisioned": mem["kv_bytes_provisioned"],
        "kv_bytes_contiguous": mem["kv_bytes_contiguous"],
    }
    return row, {r.uid: list(r.generated) for r in best_finished}


def run_paged(ctx, json_payload):
    """Paged-KV / chunked-prefill table on the ragged MoE path, plus the
    fully composed paged+pallas+EP engine (token-identity-checked)."""
    from benchmarks.common import emit_csv, record
    from repro.launch.mesh import make_serving_mesh
    from repro.parallel import ParallelConfig

    model, cfg, params = ctx.model, ctx.cfg, ctx.params
    slots, max_len = 4, 64
    page = 8
    chunk = 8
    n_short, n_long = (3, 1) if ctx.fast else (6, 2)
    long_len = 48
    max_new = 4 if ctx.fast else 8
    pc = ParallelConfig(fsdp_axis=None, weight_gather=False, ep=True)
    configs = (
        ("contiguous", {}),
        ("paged", dict(kv_layout="paged", kv_page_size=page)),
        ("paged_chunked", dict(kv_layout="paged", kv_page_size=page,
                               prefill_chunk=chunk)),
        # the tentpole composition: all three serving axes at once (the
        # mesh is trivially 1-device on the gated CPU run; the 8-device
        # version runs under --ep and in tests/test_multidevice.py)
        ("paged_pallas_ep", dict(kv_layout="paged", kv_page_size=page,
                                 attn_impl="pallas", parallel=pc,
                                 mesh=make_serving_mesh())),
    )
    rows = []
    toks = {}
    for label, kw in configs:
        row, toks[label] = _serve_paged_config(
            model, cfg, params, label=label, engine_kw=kw, n_short=n_short,
            n_long=n_long, long_len=long_len, max_new=max_new, slots=slots,
            max_len=max_len)
        rows.append(row)
        us = (1e6 / row["tokens_per_s"]) if row["tokens_per_s"] else 0.0
        emit_csv(
            f"serving_paged/{label}", us,
            f"tok_s={row['tokens_per_s']:.1f};"
            f"short_ttft_ms={row['short_ttft_s'] * 1e3:.1f};"
            f"max_step_ms={row['max_step_s'] * 1e3:.1f};"
            f"kv_peak_B={row['kv_bytes_peak']};"
            f"kv_contig_B={row['kv_bytes_contiguous']}")
    record("serving_paged", rows)

    # every KV configuration is the SAME greedy computation — the composed
    # row in particular must not drift from the contiguous/jnp engine
    for label in ("paged", "paged_chunked", "paged_pallas_ep"):
        assert toks[label] == toks["contiguous"], (
            f"{label} diverged from contiguous/jnp greedy tokens")
    print("# paged_pallas_ep greedy tokens identical to contiguous/jnp")

    by = {r["config"]: r for r in rows}
    pk, cg = by["paged"]["kv_bytes_peak"], by["paged"]["kv_bytes_contiguous"]
    if pk:
        print(f"# paged KV: peak {pk} B resident vs {cg} B contiguous "
              f"provisioning ({cg / pk:.1f}x saving on this workload, "
              f"page util {by['paged']['kv_page_util']:.2f})")
    stall_m = by["paged"]["max_step_s"]
    stall_c = by["paged_chunked"]["max_step_s"]
    if stall_m and stall_c:
        print(f"# chunked prefill: longest engine step "
              f"{stall_m * 1e3:.1f} -> {stall_c * 1e3:.1f} ms "
              f"({stall_m / stall_c:.2f}x stall reduction), short-prompt "
              f"TTFT {by['paged']['short_ttft_s'] * 1e3:.1f} -> "
              f"{by['paged_chunked']['short_ttft_s'] * 1e3:.1f} ms")
    json_payload["paged"] = {
        # the "configs" entry bumps the workload stanza for the PR that
        # added the composed row, so older baselines are skipped (not
        # gated) per the re-baselining contract in benchmarks/README.md
        "workload": {"n_short": n_short, "n_long": n_long,
                     "long_len": long_len, "max_new": max_new,
                     "slots": slots, "max_len": max_len,
                     "kv_page_size": page, "prefill_chunk": chunk,
                     "configs": [c for c, _ in configs]},
        "rows": rows,
    }


def run_prefix(ctx, json_payload):
    """Shared-system-prompt table: every request carries the same long
    prefix (a system prompt / few-shot template) plus a short distinct
    tail. The prefix-cached engine prefills the prefix ONCE; later
    requests splice the cached pages and prefill only their tail, so warm
    TTFT collapses to a single suffix-extend call. The cache-off engine
    on the identical workload is the cold reference — greedy tokens must
    match it bit-for-bit, and both engines are compile-warmed first so
    the TTFT ratio measures skipped prefill, not skipped compilation."""
    from benchmarks.common import emit_csv, record
    from repro.serving import Request, ServingConfig, ServingEngine

    model, cfg, params = ctx.model, ctx.cfg, ctx.params
    # Fixed in fast AND full modes: the table asserts behavior (hit rate,
    # parity, warm/cold separation), not throughput scaling.
    slots, max_len, page = 4, 256, 8
    prefix_len, n_requests, max_new = 240, 4, 4
    rng = np.random.RandomState(11)
    system_prompt = rng.randint(0, cfg.vocab_size, prefix_len).astype(np.int32)

    def workload(seed):
        r2 = np.random.RandomState(seed)
        return [Request(uid=i, prompt=np.concatenate(
                    [system_prompt,
                     r2.randint(0, cfg.vocab_size, 3 + i).astype(np.int32)]),
                    max_new_tokens=max_new)
                for i in range(n_requests)]

    def make_engine(prefix_cache):
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=slots, max_len=max_len, kv_layout="paged",
            kv_page_size=page, prefill_chunk=page,
            prefix_cache=prefix_cache))
        for r in workload(seed=100):  # compile warm-up, seeds the cache
            eng.submit(r)
        eng.run()
        return eng

    def serve(eng, seed):
        eng.reset_stats()
        for r in workload(seed):
            eng.submit(r)
        eng.run()
        return {r.uid: list(map(int, r.generated))
                for r in eng.finished}, eng.stats()

    # best-of-N like every other table (the gate wants a floor, not a
    # lottery ticket); fresh tails each repetition so warm requests hit
    # exactly the SHARED prefix, never their own full prompt from a
    # previous repetition
    eng_cold, eng_warm = make_engine(False), make_engine(True)
    cold = warm = None
    for rep in range(REPEATS):
        cold_toks, cold_rep = serve(eng_cold, seed=7 + rep)
        warm_toks, warm_rep = serve(eng_warm, seed=7 + rep)
        assert warm_toks == cold_toks, (
            "prefix-cached greedy tokens diverged from the cache-off "
            f"engine (repetition {rep})")
        if cold is None or cold_rep.mean_ttft_s < cold.mean_ttft_s:
            cold = cold_rep
        if warm is None or warm_rep.mean_ttft_warm_s < warm.mean_ttft_warm_s:
            warm = warm_rep
    # the warm-up pass seeded the cache with the system prompt, so every
    # measured request must splice it (the table demonstrates nothing if
    # the workload misses)
    assert warm.prefix_hit_rate > 0, "shared-prefix workload never hit"
    assert warm.kv_bytes_saved > 0
    ratio = (warm.mean_ttft_warm_s / cold.mean_ttft_s
             if cold.mean_ttft_s else float("inf"))
    rows = [{
        "config": "prefix_cache",
        "prefix_hit_rate": warm.prefix_hit_rate,
        "prefix_hits": warm.prefix_hits,
        "prefix_misses": warm.prefix_misses,
        "prefix_rows_reused": warm.prefix_rows_reused,
        "prefix_evictions": warm.prefix_evictions,
        "cow_copies": warm.cow_copies,
        "kv_bytes_saved": warm.kv_bytes_saved,
        "kv_pages_cached": warm.kv_pages_cached,
        "ttft_warm_s": warm.mean_ttft_warm_s,
        "ttft_cold_s": cold.mean_ttft_s,
        "ttft_warm_over_cold": ratio,
        "tokens_per_s_warm": warm.tokens_per_s,
        "tokens_per_s_cold": cold.tokens_per_s,
        "token_parity": True,
    }]
    record("serving_prefix", rows)
    us = (1e6 / warm.tokens_per_s) if warm.tokens_per_s else 0.0
    emit_csv("serving_prefix/prefix_cache", us,
             f"hit_rate={warm.prefix_hit_rate:.2f};"
             f"kv_saved_B={warm.kv_bytes_saved};"
             f"ttft_warm_ms={warm.mean_ttft_warm_s * 1e3:.1f};"
             f"ttft_cold_ms={cold.mean_ttft_s * 1e3:.1f}")
    print(f"# prefix cache ({prefix_len}-token shared prompt): "
          f"hit rate {warm.prefix_hit_rate:.0%}, "
          f"{warm.prefix_rows_reused} rows / {warm.kv_bytes_saved} B of "
          f"prefill KV skipped, warm TTFT "
          f"{warm.mean_ttft_warm_s * 1e3:.1f} ms vs cold "
          f"{cold.mean_ttft_s * 1e3:.1f} ms ({ratio:.2f}x)")
    json_payload["prefix"] = {
        "workload": {"prefix_len": prefix_len, "n_requests": n_requests,
                     "max_new": max_new, "slots": slots, "max_len": max_len,
                     "kv_page_size": page},
        "rows": rows,
    }


def run_speculative(ctx, json_payload):
    """Speculative-decoding table: the engine drafts with MergePlan-merged
    copies of its own target (the paper's compression artifact as a
    zero-training draft model) at two compression ratios, verifies every
    draft run in ONE batched extend, and reports acceptance rate plus the
    per-stream decode-step speedup (tokens emitted per verify dispatch a
    stream rides in — sequential decode is 1.0 by definition). Output
    parity with the non-speculative engine is asserted before anything is
    recorded: speculation changes the dispatch count, never the tokens."""
    from benchmarks.common import emit_csv, record
    from repro.core import PlanSpec, compute_plan
    from repro.serving import ServingConfig, ServingEngine, SpecConfig

    model, cfg, params = ctx.model, ctx.cfg, ctx.params
    slots, max_len, page = 4, 64, 8
    n_requests = 4 if ctx.fast else 6
    max_new = 8 if ctx.fast else 12
    k = 3
    wl = dict(n_requests=n_requests, max_new=max_new, seed=5)

    def serve(spec):
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=slots, max_len=max_len, kv_layout="paged",
            kv_page_size=page, speculative=spec))
        for r in _workload(cfg, **wl):       # warm-up: compile every shape
            eng.submit(r)
        eng.run()
        best = toks = None
        for _ in range(REPEATS):
            eng.reset_stats()
            reqs = _workload(cfg, **wl)
            for r in reqs:
                eng.submit(r)
            eng.run()
            st = eng.stats()
            if best is None or st.tokens_per_s > best.tokens_per_s:
                best = st
                toks = {r.uid: list(map(int, r.generated)) for r in reqs}
        return best, toks

    ref, ref_toks = serve(None)
    E = cfg.moe.num_experts
    targets = sorted({max(2, E // 2), 2}, reverse=True)
    rows = []
    for target in targets:
        plan = compute_plan(cfg, params, ctx.stats(),
                            PlanSpec(target_experts=target))
        st, toks = serve(SpecConfig(draft_plan=plan, k=k))
        assert toks == ref_toks, (
            f"speculative (draft {E}->{target}) diverged from the "
            "non-speculative greedy stream")
        rows.append({
            "draft_experts": target,
            "compression_ratio": E / target,
            "k": k,
            "acceptance_rate": st.acceptance_rate,
            "spec_tokens_per_round": st.spec_tokens_per_round,
            "spec_rounds": st.spec_rounds,
            "draft_tokens": st.draft_tokens,
            "draft_accepted": st.draft_accepted,
            "target_dispatches": st.decode_steps,
            "target_dispatches_sequential": ref.decode_steps,
            "dispatch_reduction": (ref.decode_steps / st.decode_steps
                                   if st.decode_steps else 0.0),
            "tokens_per_s": st.tokens_per_s,
            "tokens_per_s_sequential": ref.tokens_per_s,
            "draft_time_s": st.draft_time_s,
            "token_parity": True,
        })
        us = (1e6 / st.tokens_per_s) if st.tokens_per_s else 0.0
        emit_csv(f"serving_spec/draft{target}of{E}", us,
                 f"accept={st.acceptance_rate:.2f};"
                 f"tok_per_verify={st.spec_tokens_per_round:.2f};"
                 f"dispatch_x={rows[-1]['dispatch_reduction']:.2f};"
                 f"tok_s={st.tokens_per_s:.1f}")
        print(f"# speculative draft {E}->{target} experts "
              f"({E / target:.1f}x compressed), k={k}: acceptance "
              f"{st.acceptance_rate:.0%}, {st.spec_tokens_per_round:.2f} "
              f"tokens/stream/verify "
              f"({rows[-1]['dispatch_reduction']:.2f}x fewer target "
              f"dispatches than sequential), token parity")
    record("serving_spec", rows)
    json_payload["speculative"] = {
        "workload": {"n_requests": n_requests, "max_new": max_new,
                     "slots": slots, "max_len": max_len,
                     "kv_page_size": page, "k": k,
                     "draft_experts": targets},
        "rows": rows,
    }


def run_overload(ctx, json_payload):
    """Oversubscribed-pool table: a workload whose AGGREGATE worst-case
    page demand exceeds the pool, served under both paged admission
    policies (docs/serving_lifecycle.md). "optimistic" over-admits and
    preempts on exhaustion (recompute on re-admission); "reserve" budgets
    worst-case pages at admission and throttles instead. Both must finish
    every request with greedy tokens identical to an ample-pool reference
    — overload changes scheduling, never output."""
    from benchmarks.common import emit_csv, record
    from repro.serving import (
        Request, RequestStatus, ServingConfig, ServingEngine)

    model, cfg, params = ctx.model, ctx.cfg, ctx.params
    # Fixed workload in BOTH fast and full modes: this table measures
    # scheduling behavior (preemption counts must be deterministic and
    # nonzero), not throughput scaling — the same config the robustness
    # tests prove preempts naturally and keeps parity.
    slots, max_len, page = 2, 64, 8
    max_new = 5
    lens = (3, 20, 7, 26, 11)

    def workload():
        rng = np.random.RandomState(3)
        return [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab_size, n)
                        .astype(np.int32),
                        max_new_tokens=max_new)
                for i, n in enumerate(lens)]

    def serve(kv_pages, admission="optimistic"):
        eng = ServingEngine(model, params, config=ServingConfig(
            batch_slots=slots, max_len=max_len, kv_layout="paged",
            kv_page_size=page, kv_pages=kv_pages, admission=admission))
        reqs = workload()
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.status is RequestStatus.FINISHED for r in reqs), (
            f"overload run ({admission}, {kv_pages} pages) left "
            f"non-finished requests")
        return {r.uid: list(map(int, r.generated)) for r in reqs}, eng

    # pool sizing: aggregate worst case is sum(ceil((len+max_new)/page))
    # pages = 13 here; the pool gets 5 allocatable — every single request
    # fits alone, but concurrent decode growth must collide
    worst = sum(-(-(n + max_new) // page) for n in lens)
    pool = 6
    ref, _ = serve(kv_pages=worst + 1)          # ample: nothing preempts
    rows = []
    for admission in ("optimistic", "reserve"):
        toks, eng = serve(kv_pages=pool, admission=admission)
        assert toks == ref, (
            f"{admission} admission diverged from ample-pool greedy tokens")
        st = eng.stats()
        # scheduling is deterministic (no wall-clock inputs), so so are
        # the counts: optimistic must actually preempt on this workload,
        # reserve never does — otherwise the table demonstrates nothing
        assert (st.preemptions > 0) == (admission == "optimistic"), (
            f"{admission}: unexpected preemption count {st.preemptions}")
        rows.append({
            "admission": admission,
            "kv_pages_total": st.kv_pages_total,
            "worst_case_pages": worst,
            "tokens_per_s": st.tokens_per_s,
            "preemptions": st.preemptions,
            "mean_requeue_wait_s": st.mean_requeue_wait_s,
            "kv_pages_peak": st.kv_pages_peak,
            "kv_page_util": st.kv_page_util,
            "token_parity": True,
        })
        us = (1e6 / st.tokens_per_s) if st.tokens_per_s else 0.0
        emit_csv(f"serving_overload/{admission}", us,
                 f"tok_s={st.tokens_per_s:.1f};"
                 f"preemptions={st.preemptions};"
                 f"requeue_ms={st.mean_requeue_wait_s * 1e3:.1f};"
                 f"page_util={st.kv_page_util:.2f}")
    record("serving_overload", rows)
    opt, res = rows
    print(f"# overload ({pool}/{worst} worst-case pages): optimistic "
          f"served all requests with {opt['preemptions']} preemption(s) "
          f"(mean requeue wait {opt['mean_requeue_wait_s'] * 1e3:.1f} ms, "
          f"page util {opt['kv_page_util']:.2f}) vs reserve "
          f"{res['preemptions']} preemption(s), page util "
          f"{res['kv_page_util']:.2f}; token parity both")
    json_payload["overload"] = {
        "workload": {"prompt_lens": list(lens), "max_new": max_new,
                     "slots": slots, "max_len": max_len,
                     "kv_page_size": page, "kv_pages": pool,
                     "worst_case_pages": worst},
        "rows": rows,
    }


def run(ctx, impls=ATTN_IMPLS, json_path=JSON_PATH):
    from benchmarks.common import emit_csv, record
    from repro.kernels.flash_decode import decode_attn_accounting

    model, cfg = ctx.model, ctx.cfg
    params = ctx.params
    import tempfile

    from repro.checkpoint import load_plan, save_plan
    from repro.core import PlanSpec, apply_plan, compute_plan

    # merged rows serve a SAVED compression plan: calibration + clustering
    # run exactly once in compute_plan, the artifact round-trips through
    # disk, and every merged row below is apply_plan output — zero
    # calibration recomputation on the serving side
    spec = PlanSpec(target_experts=max(2, cfg.moe.num_experts // 2))
    with tempfile.TemporaryDirectory() as td:
        plan_path = save_plan(os.path.join(td, "plan"),
                              compute_plan(cfg, params, ctx.stats(), spec))
        merged = apply_plan(params, load_plan(plan_path))

    n_requests = 4 if ctx.fast else 8
    max_new = 4 if ctx.fast else 8
    slots, max_len = 4, 64
    rows = []
    for mode in MOE_MODES:
        for impl in impls:
            for name, p in (("unmerged", params), ("merged", merged)):
                st, _ = _serve_once(model, p, cfg, mode, attn_impl=impl,
                                    n_requests=n_requests, max_new=max_new,
                                    slots=slots, max_len=max_len)
                us_per_tok = (st.wall_time_s * 1e6 / st.total_new_tokens
                              if st.total_new_tokens else float("inf"))
                derived = (f"tok_s={st.tokens_per_s:.1f};"
                           f"ttft_ms={st.mean_ttft_s * 1e3:.1f};"
                           f"decode_ms={st.decode_step_ms:.2f};"
                           f"prefill_compiles={st.prefill_compilations}")
                emit_csv(f"serving/{name}/{mode}/{impl}", us_per_tok, derived)
                rows.append({"model": name, "moe_mode": mode,
                             "attn_impl": impl,
                             "tokens_per_s": st.tokens_per_s,
                             "mean_ttft_s": st.mean_ttft_s,
                             "mean_queue_s": st.mean_queue_s,
                             "mean_prefill_s": st.mean_prefill_s,
                             "decode_step_ms": st.decode_step_ms,
                             "decode_time_s": st.decode_time_s,
                             "total_new_tokens": st.total_new_tokens,
                             "requests": st.requests,
                             "prefill_compilations": st.prefill_compilations,
                             "decode_steps": st.decode_steps})
    record("serving", rows)

    # decode-step speedup report: pallas vs jnp per (moe_mode, model). On
    # TPU this is the measured kernel win; on CPU pallas runs interpreted
    # (pure-python grid loop), so wall-clock is meaningless there and the
    # analytic accounting below is the hardware-independent statement.
    speedups = {}
    if set(impls) >= {"jnp", "pallas"}:
        by_key = {(r["moe_mode"], r["model"], r["attn_impl"]):
                  r["decode_step_ms"] for r in rows}
        for mode in MOE_MODES:
            for name in ("unmerged", "merged"):
                a = by_key.get((mode, name, "jnp"), 0.0)
                b = by_key.get((mode, name, "pallas"), 0.0)
                if a and b:
                    speedups[f"{mode}/{name}"] = a / b
                    print(f"# decode-step jnp/pallas ratio [{mode}/{name}]: "
                          f"{a / b:.2f}x ({a:.2f} -> {b:.2f} ms)")

    # accounting at the bench's own config (tile-rounded: max_len <= 128 is
    # a single tile, so the honest ratio here is 1.0) AND at the serving
    # scale the kernel targets (batch_slots 8, max_len 2048 -> 128-row
    # tiles actually skip) — the hardware-independent statement of the win
    mean_len = float(np.mean(WORKLOAD_LENS)) + max_new
    accounting = decode_attn_accounting(cfg, slots, max_len, mean_len)
    at_scale = decode_attn_accounting(cfg, 8, 2048, mean_len)
    for tag, acc in (("bench config", accounting), ("at scale", at_scale)):
        print(f"# per-step decode-attention accounting ({tag}): "
              f"jnp reads {acc['jnp_bytes_per_step']} B/step, "
              f"flash-decode ~{acc['pallas_bytes_per_step']} B/step "
              f"({acc['byte_ratio']:.1f}x length-aware saving, "
              f"kv tile {acc['kv_tile']}, GQA group {acc['gqa_group']})")

    payload = {
        "schema": "moe path x attn impl x merged -> "
                  "{tokens_per_s, mean_ttft_s, decode_step_ms}; "
                  "+ paged: kv layout x chunking -> {tok/s, ttft, kv bytes}",
        "backend": __import__("jax").default_backend(),
        "workload": {"n_requests": n_requests, "max_new": max_new,
                     "slots": slots, "max_len": max_len,
                     "prompt_lens": list(WORKLOAD_LENS)},
        "arch": cfg.name,
        "rows": rows,
        "decode_step_speedup_jnp_over_pallas": speedups,
        "decode_attn_accounting": {"bench_config": accounting,
                                   "at_scale_b8_len2048": at_scale},
    }
    run_paged(ctx, payload)
    run_prefix(ctx, payload)
    run_overload(ctx, payload)
    run_speculative(ctx, payload)
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.abspath(json_path)}")


def run_ep(args) -> None:
    """Expert-parallel serving table: merged vs unmerged under an
    expert-sharded mesh, with per-device expert-parameter bytes."""
    import jax

    from benchmarks.common import emit_csv, record
    from repro.configs import get_config
    from repro.core import HCSMoEConfig, run_hcsmoe
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.parallel import ParallelConfig

    cfg = get_config(args.arch).reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                           (2, 32), 0, cfg.vocab_size)}
             for i in range(2)]
    target = max(2, cfg.moe.num_experts // 2)
    merged, _ = run_hcsmoe(model, params, calib,
                           HCSMoEConfig(target_experts=target))

    # default EP degree: divides BOTH expert counts, so neither model needs
    # zero-padded slots and the merged model's per-device bytes genuinely
    # shrink (padding a 4-slot merged stack back to 8 for an 8-way mesh
    # would erase the memory saving this table exists to measure)
    import math

    ep_degree = args.ep_degree or min(
        len(jax.devices()), math.gcd(cfg.moe.num_experts, target))
    if ep_degree < 2:
        # coprime counts: fall back to sharding over everything (merged
        # stacks get zero-padded, diluting their per-device saving) rather
        # than silently benchmarking with EP disabled
        ep_degree = min(len(jax.devices()), cfg.moe.num_experts)
        print(f"# NOTE: gcd({cfg.moe.num_experts}, {target}) < 2; using "
              f"ep_degree={ep_degree}, merged per-device bytes include "
              f"zero padding")
    if ep_degree < 2:
        raise RuntimeError(
            "--ep needs >= 2 devices to shard experts (found "
            f"{len(jax.devices())}); on a single-device box run under "
            "JAX_PLATFORMS=cpu so the forced "
            "xla_force_host_platform_device_count takes effect")
    mesh = make_serving_mesh(ep_degree)
    parallel = ParallelConfig(fsdp_axis=None, weight_gather=False, ep=True)
    print(f"# expert-parallel serving on {mesh}")

    n_requests = 4 if args.fast else 8
    max_new = 4 if args.fast else 8
    rows = []
    for name, p in (("unmerged", params), ("merged", merged)):
        st, engine = _serve_once(model, p, cfg, "ragged",
                                 n_requests=n_requests, max_new=max_new,
                                 parallel=parallel, mesh=mesh)
        eb = engine.expert_bytes_per_device()
        us_per_tok = (st.wall_time_s * 1e6 / st.total_new_tokens
                      if st.total_new_tokens else float("inf"))
        derived = (f"tok_s={st.tokens_per_s:.1f};"
                   f"ttft_ms={st.mean_ttft_s * 1e3:.1f};"
                   f"expert_MB_per_device={eb['max_per_device'] / 1e6:.3f};"
                   f"expert_MB_total={eb['total'] / 1e6:.3f};"
                   f"ep_degree={ep_degree}")
        emit_csv(f"serving_ep/{name}/ragged", us_per_tok, derived)
        rows.append({"model": name, "moe_mode": "ragged",
                     "ep_degree": ep_degree,
                     "tokens_per_s": st.tokens_per_s,
                     "mean_ttft_s": st.mean_ttft_s,
                     "total_new_tokens": st.total_new_tokens,
                     "requests": st.requests,
                     "expert_bytes_total": eb["total"],
                     "expert_bytes_max_per_device": eb["max_per_device"]})
        print(f"# {name}: {eb['total'] / 1e6:.3f} MB expert params total, "
              f"{eb['max_per_device'] / 1e6:.3f} MB max/device")

    # the composed engine at REAL EP degree: paged pool x flash kernels x
    # expert-sharded mesh, greedy-token-identical to single-device jnp
    toks = lambda eng: {r.uid: list(r.generated)  # noqa: E731
                        for r in eng.finished}
    _, eng_ref = _serve_once(model, params, cfg, "ragged",
                             n_requests=n_requests, max_new=max_new,
                             repeats=1)
    st, eng_c = _serve_once(model, params, cfg, "ragged",
                            n_requests=n_requests, max_new=max_new,
                            attn_impl="pallas", kv_layout="paged",
                            parallel=parallel, mesh=mesh, repeats=1)
    assert toks(eng_c) == toks(eng_ref), (
        "paged+EP+pallas diverged from the single-device jnp engine")
    km = eng_c.kv_memory()
    us_per_tok = (st.wall_time_s * 1e6 / st.total_new_tokens
                  if st.total_new_tokens else float("inf"))
    emit_csv("serving_ep/combined/paged_pallas", us_per_tok,
             f"tok_s={st.tokens_per_s:.1f};"
             f"kv_shards={km['kv_shard_degree']};"
             f"kv_peak_B_per_device={km['kv_bytes_peak_per_device']};"
             f"ep_degree={ep_degree}")
    rows.append({"model": "unmerged", "moe_mode": "ragged",
                 "attn_impl": "pallas", "kv_layout": "paged",
                 "ep_degree": ep_degree,
                 "tokens_per_s": st.tokens_per_s,
                 "tokens_match_single_device_jnp": True,
                 "kv_shard_degree": km["kv_shard_degree"],
                 "kv_bytes_peak": km["kv_bytes_peak"],
                 "kv_bytes_peak_per_device": km["kv_bytes_peak_per_device"]})
    print(f"# combined paged+pallas+EP: tokens identical to single-device "
          f"jnp; KV peak {km['kv_bytes_peak']} B "
          f"({km['kv_bytes_peak_per_device']} B/device, "
          f"{km['kv_shard_degree']}-way K/V shard)")
    record("serving_ep", rows)


def main() -> None:
    import argparse
    import sys

    if __package__ in (None, ""):  # `python benchmarks/serving_bench.py`
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ep", action="store_true",
                    help="serve under an expert-sharded (data=1, model=N) "
                         "mesh and report per-device expert-param bytes")
    ap.add_argument("--ep-degree", type=int, default=0,
                    help="EP mesh size (default: the largest degree that "
                         "divides both expert counts, so the merged model "
                         "needs no zero-padded slots)")
    ap.add_argument("--arch", default="mixtral-8x7b",
                    help="architecture for --ep mode (the non-EP table "
                         "always uses BenchContext's trained tiny model)")
    ap.add_argument("--attn-impl", default="both",
                    choices=("both", "jnp", "pallas"),
                    help="attention backend(s) for the non-EP table")
    ap.add_argument("--json", default=JSON_PATH, metavar="PATH",
                    help="where to write the BENCH_serving.json baseline")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    if args.ep and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # must happen before the first jax import anywhere in this process
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()

    if args.ep:
        run_ep(args)
    else:
        from benchmarks.common import BenchContext

        impls = ATTN_IMPLS if args.attn_impl == "both" else (args.attn_impl,)
        run(BenchContext(fast=args.fast), impls=impls, json_path=args.json)


if __name__ == "__main__":
    main()
