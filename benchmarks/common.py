"""Shared benchmark harness.

Trains a tiny-but-real MoE LM (domain-structured synthetic data so experts
specialise), caches it on disk, and provides the evaluation protocol used by
every paper-table benchmark: 4 synthetic zero-shot "tasks" (distinct domain
mixtures, analogous to the paper's 8 LM-Harness tasks) scored by eval CE
loss — lower is better; "Average" mirrors the paper's average column.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import collect_moe_stats
from repro.data import TokenStream
from repro.models import build_model
from repro.parallel import ParallelConfig
from repro.training import OptimizerConfig, init_opt_state, make_train_step

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "bench_cache")
RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "benchmarks.json")

# the evaluation "tasks": distinct domain SUBSETS of the training
# distribution (seed 0), sampled from held-out step ranges — analogous to the
# paper's zero-shot task suite (each task exercises different experts)
TASKS = {
    "taskA": dict(seed=0, n_domains=8, domain_subset=(0, 1)),
    "taskB": dict(seed=0, n_domains=8, domain_subset=(2, 3)),
    "taskC": dict(seed=0, n_domains=8, domain_subset=(4, 5)),
    "taskD": dict(seed=0, n_domains=8, domain_subset=(6, 7)),
}
EVAL_STEP_OFFSET = 50_000  # held-out region of the deterministic stream


class BenchContext:
    def __init__(self, *, arch="qwen1.5-moe-a2.7b", num_experts=12, top_k=2,
                 steps=500, fast=False):
        import dataclasses

        base = get_config(arch).reduced(dtype="float32")
        self.cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, num_experts=num_experts,
                                          top_k=top_k))
        self.steps = 60 if fast else steps
        self.model = build_model(self.cfg)
        self.fast = fast
        self._params = None
        self._stats = None

    # ------------------------------------------------------------- train
    @property
    def params(self):
        if self._params is None:
            self._params = self._train_or_load()
        return self._params

    def _train_or_load(self):
        os.makedirs(CACHE_DIR, exist_ok=True)
        tag = f"{self.cfg.name}_{self.cfg.moe.num_experts}e_{self.steps}s"
        path = os.path.join(CACHE_DIR, tag + ".npz")
        model = self.model
        params0 = model.init(jax.random.PRNGKey(0))
        if os.path.exists(path):
            data = np.load(path)
            flat, treedef = jax.tree_util.tree_flatten(params0)
            leaves = [jnp.asarray(data[f"a{i}"]) for i in range(len(flat))]
            return jax.tree_util.tree_unflatten(treedef, leaves)
        stream = TokenStream(self.cfg.vocab_size, seq_len=32, global_batch=8,
                             seed=0, n_domains=8)
        oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=10,
                             total_steps=self.steps, weight_decay=0.0)
        step = jax.jit(make_train_step(
            model, oc, ParallelConfig(remat="none", moe_mode="dense")))
        params, opt = params0, init_opt_state(params0)
        for i in range(self.steps):
            batch = jax.tree.map(jnp.asarray, stream.batch(i))
            params, opt, m = step(params, opt, batch)
        flat, _ = jax.tree_util.tree_flatten(params)
        np.savez(path, **{f"a{i}": np.asarray(x) for i, x in enumerate(flat)})
        return params

    # -------------------------------------------------------- calibration
    def stats(self, *, n_batches=3):
        """C4-analog calibration stats: general mixture over ALL training
        domains, held-out step range (paper: 32x2048 C4 tokens)."""
        if self._stats is None:
            self._stats = self.stats_for(seed=0, n_batches=n_batches,
                                         n_domains=8)
        return self._stats

    def stats_for(self, *, seed, n_batches=3, n_domains=8, domain_subset=()):
        stream = TokenStream(self.cfg.vocab_size, seq_len=64, global_batch=4,
                             seed=seed, n_domains=n_domains,
                             domain_subset=domain_subset)
        calib = [{"tokens": jnp.asarray(stream.batch(10_000 + i)["tokens"])}
                 for i in range(n_batches)]
        return collect_moe_stats(self.model, self.params, calib)

    # --------------------------------------------------------------- eval
    def eval_model(self, params) -> dict:
        """Per-task eval loss + Average (lower is better)."""
        from repro.core.quality import eval_loss

        out = {}
        for task, kw in TASKS.items():
            stream = TokenStream(self.cfg.vocab_size, seq_len=32,
                                 global_batch=8, **kw)
            batches = [jax.tree.map(jnp.asarray,
                                    stream.batch(EVAL_STEP_OFFSET + i))
                       for i in range(2 if self.fast else 4)]
            out[task] = eval_loss(self.model, params, batches,
                                  moe_mode="dense")
        out["Average"] = float(np.mean(list(out.values())))
        return out


_RESULTS = {}


def record(table: str, rows):
    _RESULTS[table] = rows
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    existing = {}
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            existing = json.load(f)
    existing[table] = rows
    with open(RESULTS_PATH, "w") as f:
        json.dump(existing, f, indent=1)


def emit_csv(name: str, us_per_call: float, derived):
    """The bench runner contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = jax.block_until_ready(fn(*args, **kw))
    return out, (time.time() - t0) * 1e6
