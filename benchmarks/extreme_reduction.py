"""Tables 18-19 analog: extreme reduction (62.5% / 75%) + algorithm runtimes.
Expectation: baselines collapse toward random while HC-SMoE stays above."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe
from repro.core import baselines as bl

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    E = cfg.moe.num_experts
    rows = []
    for frac, label in [(0.375, "62.5%"), (0.25, "75%")]:
        r = max(1, int(round(E * frac)))
        variants = [
            ("F-prune", lambda: bl.f_prune(cfg, params, stats, r)[0]),
            ("S-prune", lambda: bl.s_prune(cfg, params, stats, r)[0]),
            ("O-prune", lambda: bl.o_prune(cfg, params, stats, r,
                                           samples=24)[0]),
            ("M-SMoE", lambda: bl.m_smoe(cfg, params, stats, r)[0]),
            ("HC-SMoE", lambda: apply_hcsmoe(
                cfg, params, stats, HCSMoEConfig(target_experts=r))[0]),
        ]
        for name, fn in variants:
            merged, us = timed(fn)
            row = {"method": name, "r": r, "reduction": label,
                   "algo_time_s": us / 1e6, **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"extreme/{label}/{name}", us, row["Average"])
    record("table18_19_extreme", rows)
    return rows
