"""Roofline report: reads the dry-run artifacts (results/dryrun/*.json) and
prints the per-(arch × shape × mesh) three-term roofline table (§Roofline)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit_csv, record

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def run(ctx=None):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") == "skipped":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": d["mesh"], "status": "skipped",
                         "reason": d["reason"]})
            continue
        if d.get("status") != "ok":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": d.get("mesh"), "status": d.get("status"),
                         "error": d.get("error", "")[:200]})
            continue
        r = d["roofline"]
        mf = d["model_flops"]
        row = {
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "tag": d.get("tag", ""), "status": "ok",
            "compute_s": round(r["compute_s"], 5),
            "memory_s": round(r["memory_s"], 5),
            "collective_s": round(r["collective_s"], 5),
            "bottleneck": r["bottleneck"],
            "step_lb_s": round(r["step_time_lower_bound_s"], 5),
            "mem_GiB_per_dev": round(
                d["memory"].get("total_bytes_per_device", 0) / 2**30, 2),
            "useful_flops_ratio": round(mf["useful_ratio"], 3),
            "roofline_fraction": round(
                r["compute_s"] / max(r["step_time_lower_bound_s"], 1e-12), 4),
        }
        rows.append(row)
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        if d.get("tag"):
            name += f"/{d['tag']}"
        emit_csv(name, 0.0, row["step_lb_s"])
    record("roofline", rows)
    return rows
