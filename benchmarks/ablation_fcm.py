"""Tables 16-17 analog: hard HC vs soft Fuzzy C-means clustering."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    rows = []
    for frac, label in [(0.75, "25%"), (0.5, "50%")]:
        r = max(1, int(round(cfg.moe.num_experts * frac)))
        for clustering in ["hc", "fcm"]:
            hc = HCSMoEConfig(target_experts=r, clustering=clustering,
                              resize=(clustering == "hc"))
            merged, us = timed(lambda: apply_hcsmoe(cfg, params, stats, hc)[0])
            row = {"clustering": clustering, "reduction": label,
                   **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"fcm/{label}/{clustering}", us, row["Average"])
    record("table16_17_fcm", rows)
    return rows
