"""Table 6 analog: single-shot grouping (Li et al.) per metric vs HC-SMoE."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe
from repro.core import baselines as bl

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    rows = []
    for frac, label in [(0.75, "25%"), (0.5, "50%")]:
        r = max(1, int(round(cfg.moe.num_experts * frac)))
        for metric in ["router_logits", "weight", "expert_output"]:
            merged, us = timed(
                lambda m=metric: bl.m_smoe(cfg, params, stats, r,
                                           metric=m)[0])
            row = {"grouping": "one-shot", "metric": metric, "reduction": label,
                   **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"oneshot/{label}/{metric}", us, row["Average"])
        merged, us = timed(lambda: apply_hcsmoe(
            cfg, params, stats, HCSMoEConfig(target_experts=r))[0])
        row = {"grouping": "HC-SMoE", "metric": "expert_output",
               "reduction": label, **ctx.eval_model(merged)}
        rows.append(row)
        emit_csv(f"oneshot/{label}/HC-SMoE", us, row["Average"])
    record("table6_oneshot_vs_hc", rows)
    return rows
