"""Table 20 analog: computational/memory efficiency of merged models.

Analytic params/GFLOPs/memory for the REAL configs (mixtral & qwen at the
paper's reduction points + every assigned MoE arch at 25/50%), plus measured
tiny-model serving throughput before/after merging.
"""
from __future__ import annotations

import time

import dataclasses
import numpy as np

from repro.configs import get_config

from benchmarks.common import emit_csv, record


def _reduced_cfg(cfg, r):
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=r))


def analytic_rows():
    rows = []
    cases = {
        "mixtral-8x7b": [8, 6, 4],
        "qwen1.5-moe-a2.7b": [60, 45, 30],
        "deepseek-v2-236b": [160, 120, 80],
        "moonshot-v1-16b-a3b": [64, 48, 32],
        "jamba-v0.1-52b": [16, 12, 8],
    }
    for arch, rs in cases.items():
        cfg = get_config(arch)
        for r in rs:
            c = _reduced_cfg(cfg, r)
            total, active = c.param_counts()
            # per-token fwd GFLOPs and bf16 memory
            rows.append({
                "arch": arch, "experts": r,
                "params_B": round(total / 1e9, 2),
                "active_params_B": round(active / 1e9, 2),
                "fwd_GFLOPs_per_tok": round(2 * active / 1e9, 2),
                "weights_GB_bf16": round(total * 2 / 2**30, 2),
            })
    return rows


def measured_throughput(ctx):
    """Tiny-model serving tokens/s before vs after 50% merging."""
    import numpy as np

    from repro.core import HCSMoEConfig, apply_hcsmoe
    from repro.serving import Request, ServingConfig, ServingEngine

    cfg, model, params = ctx.cfg, ctx.model, ctx.params
    stats = ctx.stats()
    r = max(1, cfg.moe.num_experts // 2)
    merged, _ = apply_hcsmoe(cfg, params, stats,
                             HCSMoEConfig(target_experts=r))
    out = {}
    for name, p in [("original", params), ("merged50", merged)]:
        eng = ServingEngine(model, p, config=ServingConfig(
            batch_slots=4, max_len=64, moe_mode="dense"))
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i, prompt=rng.randint(
            0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=8)
            for i in range(4)]
        for req in reqs:
            eng.submit(req)
        eng.step()  # warm up compile
        # engine.run() syncs every step (token readback into rq.generated),
        # so the region is already materialised when the clock stops
        t0 = time.time()
        eng.run()
        dt = time.time() - t0  # noqa: RPR005
        toks = sum(len(rq.generated) for rq in reqs)
        out[name] = toks / dt
    return out


def run(ctx):
    rows = analytic_rows()
    for row in rows:
        emit_csv(f"efficiency/{row['arch']}/{row['experts']}e", 0.0,
                 row["weights_GB_bf16"])
    thr = measured_throughput(ctx)
    rows.append({"measured_tok_per_s": thr})
    emit_csv("efficiency/tiny_throughput_orig", 0.0, round(thr["original"], 1))
    emit_csv("efficiency/tiny_throughput_merged", 0.0,
             round(thr["merged50"], 1))
    record("table20_efficiency", rows)
    return rows
