"""Table 5 analog: K-means (fix/rnd init × metric) vs HC at 50% reduction,
including the init-sensitivity spread over seeds."""
from __future__ import annotations

import numpy as np

from repro.core import HCSMoEConfig, apply_hcsmoe

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    r = max(1, cfg.moe.num_experts // 2)
    rows = []
    for clustering in ["kmeans_fix", "kmeans_rnd"]:
        for metric in ["router_logits", "weight", "expert_output"]:
            hc = HCSMoEConfig(target_experts=r, clustering=clustering,
                              metric=metric)
            merged, us = timed(lambda: apply_hcsmoe(cfg, params, stats, hc)[0])
            row = {"clustering": clustering, "metric": metric,
                   **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"kmeans/{clustering}/{metric}", us, row["Average"])
    # HC reference
    merged, us = timed(lambda: apply_hcsmoe(
        cfg, params, stats, HCSMoEConfig(target_experts=r))[0])
    row = {"clustering": "hc", "metric": "expert_output",
           **ctx.eval_model(merged)}
    rows.append(row)
    emit_csv("kmeans/hc/expert_output", us, row["Average"])

    # init-sensitivity: spread of kmeans_rnd across seeds vs HC determinism
    spreads = []
    for seed in range(4):
        hc = HCSMoEConfig(target_experts=r, clustering="kmeans_rnd",
                          metric="expert_output", seed=seed)
        merged, _ = timed(lambda: apply_hcsmoe(cfg, params, stats, hc)[0])
        spreads.append(ctx.eval_model(merged)["Average"])
    rows.append({"clustering": "kmeans_rnd_seed_spread",
                 "spread": float(np.max(spreads) - np.min(spreads)),
                 "values": spreads})
    emit_csv("kmeans/rnd_seed_spread", 0.0,
             float(np.max(spreads) - np.min(spreads)))
    record("table5_kmeans_vs_hc", rows)
    return rows
