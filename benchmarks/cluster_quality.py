"""Table 23 analog: cluster quality (silhouette/Dunn, euclidean & cosine) and
last-layer output fidelity (L2 / cosine) for HC vs K-means × metric.

Each row is one :class:`repro.core.plan.MergePlan`: clustering runs ONCE in
``compute_plan`` (the quality metrics read the plan's own labels/features),
and the merged model is ``apply_plan`` output — the old double work of
``apply_hcsmoe`` + a second ``compute_groupings`` pass is gone.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import PlanSpec, apply_plan, compute_plan
from repro.core.quality import cluster_quality_report, output_fidelity
from repro.data import TokenStream

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, model, params = ctx.cfg, ctx.model, ctx.params
    stats = ctx.stats()
    stream = TokenStream(cfg.vocab_size, seq_len=32, global_batch=4, seed=555)
    fid_batches = [{"tokens": jnp.asarray(stream.batch(i)["tokens"])}
                   for i in range(2)]
    rows = []
    for frac, label in [(0.75, "25%"), (0.5, "50%")]:
        r = max(1, int(round(cfg.moe.num_experts * frac)))
        for clustering in ["hc", "kmeans_rnd"]:
            for metric in ["expert_output", "weight", "router_logits"]:
                spec = PlanSpec(target_experts=r, clustering=clustering,
                                metric=metric)
                plan, us_plan = timed(
                    lambda: compute_plan(cfg, params, stats, spec))
                merged, us_apply = timed(lambda: apply_plan(params, plan))
                qual = [cluster_quality_report(lp.extras["features"],
                                               lp.labels)
                        for lp in plan.layers]
                qual_avg = {k: float(np.mean([q[k] for q in qual]))
                            for k in qual[0]}
                fid = output_fidelity(model, params, merged, fid_batches,
                                      moe_mode="dense")
                row = {"reduction": label, "clustering": clustering,
                       "metric": metric, **fid, **qual_avg}
                rows.append(row)
                emit_csv(f"quality23/{label}/{clustering}/{metric}",
                         us_plan + us_apply, fid["l2_error"])
    record("table23_cluster_quality", rows)
    return rows
