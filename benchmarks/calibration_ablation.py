"""Tables 10-11 analog: calibration-set independence. Three calibration
distributions (general / narrow "MATH"-like / shifted "Code"-like domain
mixes) should yield near-identical merged quality."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe

from benchmarks.common import emit_csv, record, timed

# same transition tables (seed 0); different DOMAIN mixtures, mirroring the
# paper's C4 (general) vs MATH / CodeQA (narrow-domain) calibration sets
CALIBS = {
    "C4-like": dict(seed=0, n_domains=8),
    "MATH-like": dict(seed=0, n_domains=8, domain_subset=(0,)),
    "CodeQA-like": dict(seed=0, n_domains=8, domain_subset=(6, 7)),
}


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    rows = []
    for frac, label in [(0.75, "25%"), (0.5, "50%")]:
        r = max(1, int(round(cfg.moe.num_experts * frac)))
        for name, kw in CALIBS.items():
            stats = ctx.stats_for(**kw)
            merged, us = timed(lambda: apply_hcsmoe(
                cfg, params, stats, HCSMoEConfig(target_experts=r))[0])
            row = {"calib": name, "reduction": label,
                   **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"calib/{label}/{name}", us, row["Average"])
    record("table10_11_calibration", rows)
    return rows
