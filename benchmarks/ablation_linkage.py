"""Table 4 analog: linkage (single/complete/average) × similarity metric
(router-logits / weight / expert-output) at 25% reduction."""
from __future__ import annotations

from repro.core import HCSMoEConfig, apply_hcsmoe

from benchmarks.common import emit_csv, record, timed


def run(ctx):
    cfg, params = ctx.cfg, ctx.params
    stats = ctx.stats()
    r = max(1, int(round(cfg.moe.num_experts * 0.75)))
    rows = []
    for linkage in ["single", "complete", "average"]:
        for metric in ["router_logits", "weight", "expert_output"]:
            hc = HCSMoEConfig(target_experts=r, linkage=linkage, metric=metric)
            merged, us = timed(lambda: apply_hcsmoe(cfg, params, stats, hc)[0])
            row = {"linkage": linkage, "metric": metric,
                   **ctx.eval_model(merged)}
            rows.append(row)
            emit_csv(f"linkage/{linkage}/{metric}", us, row["Average"])
    record("table4_linkage_metric", rows)
    return rows
