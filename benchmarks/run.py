"""Benchmark runner — one module per paper table.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only quality_main,...]

Prints ``name,us_per_call,derived`` CSV per the harness contract and writes
structured rows to results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import sys
import time

TABLES = [
    ("quality_main", "benchmarks.quality_main"),          # Tables 2-3
    ("ablation_linkage", "benchmarks.ablation_linkage"),  # Table 4
    ("ablation_kmeans", "benchmarks.ablation_kmeans"),    # Table 5
    ("ablation_oneshot", "benchmarks.ablation_oneshot"),  # Table 6
    ("ablation_merging", "benchmarks.ablation_merging"),  # Table 7
    ("nonuniform", "benchmarks.nonuniform"),              # Table 8
    ("calibration_ablation", "benchmarks.calibration_ablation"),  # T10-11
    ("ablation_fcm", "benchmarks.ablation_fcm"),          # Tables 16-17
    ("extreme_reduction", "benchmarks.extreme_reduction"),  # Tables 18-19
    ("efficiency", "benchmarks.efficiency"),              # Table 20
    ("serving", "benchmarks.serving_bench"),              # deployment story
    ("cluster_quality", "benchmarks.cluster_quality"),    # Table 23
    ("roofline_bench", "benchmarks.roofline_bench"),      # Roofline section
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer train steps / eval batches")
    ap.add_argument("--only", default=None,
                    help="comma-separated table subset")
    args = ap.parse_args()

    from benchmarks.common import BenchContext

    only = set(args.only.split(",")) if args.only else None
    ctx = BenchContext(fast=args.fast)
    print("name,us_per_call,derived")
    t_all = time.time()
    failures = []
    for name, module in TABLES:
        if only and name not in only:
            continue
        # host wall-clock per table (subprocess-style aggregate), not a
        # kernel measurement — per-op timing happens inside each module
        t0 = time.time()  # noqa: RPR005
        print(f"# --- {name} ---", flush=True)
        try:
            import importlib

            mod = importlib.import_module(module)
            mod.run(ctx)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"# FAILED {name}: {e!r}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.time() - t_all:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
