"""Bench-regression gate: compare a fresh ``serving_bench.py --json`` run
against the committed ``results/BENCH_serving.json`` baseline.

CI runs::

  PYTHONPATH=src python benchmarks/serving_bench.py --fast --json fresh.json
  python benchmarks/check_regression.py --fresh fresh.json

and fails the job when any (model, moe_mode, attn_impl) row regresses
beyond ``--tolerance`` (default 2.0x — generous, because CI boxes are
noisy CPU runners and the pallas backend runs in interpret mode there):

* ``decode_step_ms``  must not exceed ``baseline * tolerance``
* ``tokens_per_s``    must not drop below ``baseline / tolerance``

The paged table (``paged.rows``, keyed by ``config``) is gated on
``tokens_per_s`` the same way, and the speculative table
(``speculative.rows``, keyed by ``draft_experts``) on ``tokens_per_s``
AND ``acceptance_rate`` — a draft/target divergence that silently
collapses acceptance is a regression even when wall-clock survives it.
Rows present on only one side are reported
but never fail the gate (new configurations must be able to land before
they have a baseline). Runs on a different jax backend skip the whole
gate with exit 0; a table whose own workload stanza changed is skipped
per-table — comparing either would gate on noise, not regressions. The
bench records each row's best-of-N timed repetition (compile excluded),
so the numbers being compared are floors, not single noisy samples.

Re-baselining: see benchmarks/README.md (short version: re-run the bench
with ``--fast --json results/BENCH_serving.json`` and commit the result
together with the change that legitimately moved the numbers).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_serving.json")


def _key(row) -> tuple:
    return (row["model"], row["moe_mode"], row["attn_impl"])


def _index(payload, table: str, keyfn):
    rows = (payload.get("rows", []) if table == "rows"
            else payload.get(table, {}).get("rows", []))
    return {keyfn(r): r for r in rows}


def _check_metric(name, key, base, fresh, tol, worse_high: bool):
    """Returns (verdict, message); verdict True = regression."""
    if not base or not fresh or base <= 0 or fresh <= 0:
        return False, None
    ratio = fresh / base
    if worse_high:
        bad = ratio > tol
        arrow = f"{base:.3g} -> {fresh:.3g} ({ratio:.2f}x, limit {tol:.1f}x)"
    else:
        bad = ratio < 1.0 / tol
        arrow = (f"{base:.3g} -> {fresh:.3g} ({ratio:.2f}x, "
                 f"limit {1.0 / tol:.2f}x)")
    tag = "REGRESSION" if bad else "ok"
    return bad, f"  [{tag}] {'/'.join(map(str, key))} {name}: {arrow}"


def compare(base: dict, fresh: dict, tolerance: float) -> int:
    if base.get("backend") != fresh.get("backend"):
        print(f"# backend changed ({base.get('backend')} -> "
              f"{fresh.get('backend')}): baseline not comparable, skipping "
              "gate (re-baseline on the new backend)")
        return 0

    regressions = 0
    checked = 0
    # each table carries its own workload stanza; a changed workload makes
    # THAT table incomparable (skip + re-baseline) without silencing the
    # gate on the other
    for table, keyfn, metrics, wl in (
        ("rows", _key, (("decode_step_ms", True), ("tokens_per_s", False)),
         "workload"),
        ("paged", lambda r: (r["config"],), (("tokens_per_s", False),),
         "paged workload"),
        # speculative: throughput must hold AND the draft must stay useful
        # — a silent acceptance-rate collapse (draft/target divergence)
        # fails the gate even if wall-clock happens to survive it
        ("speculative", lambda r: (r["draft_experts"],),
         (("tokens_per_s", False), ("acceptance_rate", False)),
         "speculative workload"),
    ):
        if table == "rows":
            b_wl, f_wl = base.get("workload"), fresh.get("workload")
        else:
            b_wl = base.get(table, {}).get("workload")
            f_wl = fresh.get(table, {}).get("workload")
        if b_wl != f_wl:
            print(f"# {wl} changed vs baseline: skipping the '{table}' "
                  "table (re-baseline with the new workload)")
            continue
        b_rows = _index(base, table, keyfn)
        f_rows = _index(fresh, table, keyfn)
        for k in sorted(set(b_rows) | set(f_rows), key=str):
            if k not in b_rows:
                print(f"  [new] {'/'.join(map(str, k))}: no baseline yet")
                continue
            if k not in f_rows:
                print(f"  [gone] {'/'.join(map(str, k))}: row vanished from "
                      "the fresh run (bench coverage shrank?)")
                continue
            for metric, worse_high in metrics:
                bad, msg = _check_metric(metric, k, b_rows[k].get(metric),
                                         f_rows[k].get(metric), tolerance,
                                         worse_high)
                if msg:
                    checked += 1
                    print(msg)
                if bad:
                    regressions += 1
    print(f"# {checked} metric(s) checked, {regressions} regression(s) at "
          f"{tolerance:.1f}x tolerance")
    return 1 if regressions else 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE,
                    help="committed baseline JSON (default: "
                         "results/BENCH_serving.json)")
    ap.add_argument("--fresh", required=True,
                    help="JSON written by this run's serving_bench.py")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="allowed slowdown factor before the gate fails "
                         "(default 2.0 — CPU CI noise headroom)")
    args = ap.parse_args()
    if args.tolerance <= 1.0:
        ap.error("--tolerance must be > 1.0")
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    return compare(base, fresh, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
