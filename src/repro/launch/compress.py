"""Compression-plan CLI: compute / inspect / apply a MergePlan offline.

The plan is the deployable artifact of retraining-free compression
(``docs/compression_api.md``): calibration + clustering run ONCE here, the
resulting JSON+npz directory is what serving, benchmarks, and CI consume.

  # stage 1 (calibration-dependent): compute and save a plan
  PYTHONPATH=src python -m repro.launch.compress compute \
      --arch mixtral-8x7b --reduced --target 4 --out /tmp/plan

  # audit provenance (method, metric, per-layer targets, feature hashes)
  PYTHONPATH=src python -m repro.launch.compress inspect /tmp/plan

  # stage 2 (calibration-free): apply to params and save a checkpoint
  PYTHONPATH=src python -m repro.launch.compress apply \
      --arch mixtral-8x7b --reduced /tmp/plan --out-checkpoint /tmp/merged

  # serve it (applies the plan at engine load time)
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --reduced --merge-plan /tmp/plan

``--checkpoint DIR`` (compute/apply) starts from a saved params checkpoint
instead of the seeded init; defaults match ``serve.py --merge-to`` so the
CI compress->serve smoke is token-identical to in-memory merging.
"""
from __future__ import annotations

import argparse
import time


def _build(args):
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.init_seed))
    if args.checkpoint:
        from repro.checkpoint import CheckpointManager

        restored, step = CheckpointManager(args.checkpoint).restore(
            {"params": params})
        params = restored["params"]
        print(f"restored params from {args.checkpoint} @ step {step}")
    return cfg, model, params


def cmd_compute(args) -> None:
    from repro.checkpoint import save_plan
    from repro.core import PlanSpec, compute_plan, plan_summary
    from repro.core import baselines  # noqa: F401  (registers planners)
    from repro.core.calibration import collect_moe_stats
    from repro.core.registry import PLANNERS
    from repro.data import calibration_batches

    cfg, model, params = _build(args)
    if cfg.moe is None:
        raise SystemExit(f"{cfg.name} has no MoE layers to compress")
    # per-method metric default declared by the planner itself
    metric = args.metric or getattr(PLANNERS.get(args.method),
                                    "default_metric", "expert_output")
    spec = PlanSpec(
        target_experts=args.target, method=args.method,
        metric=metric, clustering=args.clustering,
        linkage=args.linkage, merge=args.merge,
        fix_dom_feature=args.fix_dom_feature,
        non_uniform=args.non_uniform, resize=not args.no_resize,
        seed=args.seed, samples=args.samples)
    calib = calibration_batches(cfg, n_seqs=args.calib_seqs,
                                seq_len=args.calib_len,
                                batch=args.calib_batch)
    t0 = time.time()
    stats = collect_moe_stats(model, params, calib)
    t1 = time.time()
    plan = compute_plan(cfg, params, stats, spec)
    t2 = time.time()
    path = save_plan(args.out, plan)
    print(plan_summary(plan))
    print(f"calibration {t1 - t0:.1f}s, planning {t2 - t1:.1f}s")
    print(f"saved plan to {path}")


def cmd_inspect(args) -> None:
    from repro.checkpoint import load_plan
    from repro.core import plan_summary

    print(plan_summary(load_plan(args.plan)))


def cmd_apply(args) -> None:
    from repro.checkpoint import CheckpointManager, load_plan
    from repro.core import apply_plan

    cfg, model, params = _build(args)
    plan = load_plan(args.plan)
    t0 = time.time()
    merged = apply_plan(params, plan, executor=args.executor or None)
    print(f"applied {plan.method} plan ({plan.num_experts} -> {plan.slots} "
          f"slots, {plan.num_layers} layers) in {time.time() - t0:.1f}s")
    mgr = CheckpointManager(args.out_checkpoint, keep=1)
    out = mgr.save(0, {"params": merged,
                       "meta": {"merge_plan": plan.spec,
                                "plan_method": plan.method,
                                "arch": cfg.name}})
    print(f"saved merged checkpoint to {out}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def model_flags(p):
        p.add_argument("--arch", default="mixtral-8x7b")
        p.add_argument("--reduced", action="store_true")
        p.add_argument("--init-seed", type=int, default=0)
        p.add_argument("--checkpoint", default="",
                       help="restore params from this checkpoint dir "
                            "instead of the seeded init")

    pc = sub.add_parser("compute", help="calibrate and save a plan")
    model_flags(pc)
    pc.add_argument("--target", type=int, required=True,
                    help="target experts per layer")
    pc.add_argument("--method", default="hc_smoe",
                    help="planner: hc_smoe | f_prune | s_prune | o_prune | "
                         "m_smoe (extensible via @register_planner)")
    pc.add_argument("--metric", default="",
                    help="similarity metric (default: expert_output; "
                         "m_smoe defaults to router_logits per the paper)")
    pc.add_argument("--clustering", default="hc")
    pc.add_argument("--linkage", default="average")
    pc.add_argument("--merge", default="frequency")
    pc.add_argument("--fix-dom-feature", default="act")
    pc.add_argument("--non-uniform", action="store_true")
    pc.add_argument("--no-resize", action="store_true")
    pc.add_argument("--seed", type=int, default=0)
    pc.add_argument("--samples", type=int, default=64,
                    help="o_prune subset-search budget")
    pc.add_argument("--calib-seqs", type=int, default=8)
    pc.add_argument("--calib-len", type=int, default=128)
    pc.add_argument("--calib-batch", type=int, default=4)
    pc.add_argument("--out", required=True, help="plan output directory")
    pc.set_defaults(fn=cmd_compute)

    pi = sub.add_parser("inspect", help="print a saved plan's provenance")
    pi.add_argument("plan", help="plan directory")
    pi.set_defaults(fn=cmd_inspect)

    pa = sub.add_parser("apply", help="apply a saved plan to params and "
                                      "save the merged checkpoint")
    model_flags(pa)
    pa.add_argument("plan", help="plan directory")
    pa.add_argument("--executor", default="", choices=("", "jax", "numpy"),
                    help="override the plan's default executor")
    pa.add_argument("--out-checkpoint", required=True)
    pa.set_defaults(fn=cmd_apply)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
