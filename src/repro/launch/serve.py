"""Serving launcher: continuous-batching generation with optional HC-SMoE
merging, expert-parallel sharding, per-request sampling, and engine
telemetry.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --merge-to 4 --requests 6 --temperature 0.7 --top-p 0.9 \
      --attn-impl pallas

Serving a saved compression plan (computed offline by
``python -m repro.launch.compress compute``; the engine applies it to the
params at load time — no calibration in the serving process):

  PYTHONPATH=src python -m repro.launch.serve --reduced --merge-plan /tmp/plan

Expert-parallel serving (shards every MoE expert stack over the 'model'
axis; on a CPU dev box force a multi-device view first):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --reduced --ep --merge-to 4

Cross-request prefix caching (shared system prompt, paged layout only):

  PYTHONPATH=src python -m repro.launch.serve --reduced --kv-layout paged \
      --prefix-cache --shared-prefix 24

Speculative decoding (the draft model is an aggressively-merged plan from
``launch/compress.py compute`` applied to the same base params; the target
verifies every drafted token, so output streams are token-identical to a
non-speculative run):

  PYTHONPATH=src python -m repro.launch.serve --reduced --kv-layout paged \
      --spec-draft-plan /tmp/plan --spec-k 4

Every engine flag is registered by ``ServingConfig.add_cli_args`` and
consumed by ``ServingConfig.from_args`` — this launcher only owns the
WORKLOAD flags (model choice, request count, prompt shape, sampling).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    Request, SamplingParams, ServingConfig, ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--merge-to", type=int, default=0,
                    help="HC-SMoE: merge experts to this count before serving")
    ap.add_argument("--merge-plan", default="",
                    help="saved MergePlan directory (launch/compress.py); "
                         "applied to the params at engine load time")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="first N prompt tokens identical across requests "
                         "(a shared system prompt); pair with "
                         "--prefix-cache to exercise cross-request reuse")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples with per-request seeds")
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds from submission; "
                         "overdue requests are EXPIRED at the next step "
                         "boundary (0 = no deadline)")
    ServingConfig.add_cli_args(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.merge_to and args.merge_plan:
        raise SystemExit("--merge-to recalibrates in-process; --merge-plan "
                         "serves a precomputed plan — pick one")
    merge_plan = None
    if args.merge_plan:
        from repro.checkpoint import load_plan

        merge_plan = load_plan(args.merge_plan)
        print(f"serving {merge_plan.method} plan from {args.merge_plan} "
              f"({merge_plan.num_experts} -> {merge_plan.slots} slots, "
              f"{merge_plan.num_layers} layers)")
    if args.merge_to and cfg.moe is not None:
        from repro.core import HCSMoEConfig, run_hcsmoe
        from repro.data import calibration_batches

        calib = calibration_batches(cfg, n_seqs=8, seq_len=128, batch=4)
        t0 = time.time()
        params, _ = run_hcsmoe(model, params, calib,
                               HCSMoEConfig(target_experts=args.merge_to))
        print(f"HC-SMoE merged {cfg.moe.num_experts} -> {args.merge_to} "
              f"experts/layer in {time.time() - t0:.1f}s")

    config = ServingConfig.from_args(
        args, max_len=args.max_len or args.prompt_len + args.max_new + 8,
        merge_plan=merge_plan)
    if config.mesh is not None:
        print(f"expert-parallel serving on {config.mesh}")
    if config.faults is not None:
        print(f"chaos armed: seed={args.chaos_seed} "
              f"preempt_every={args.chaos_preempt_every} "
              f"exhaust_prob={args.chaos_exhaust_prob}")
    engine = ServingEngine(model, params, config=config)
    if config.mesh is not None:
        eb = engine.expert_bytes_per_device()
        print(f"expert params: {eb['total'] / 1e6:.2f} MB total, "
              f"{eb['max_per_device'] / 1e6:.2f} MB max/device "
              f"({config.mesh.shape['model']}-way EP)")
    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab_size,
                         min(args.shared_prefix, args.prompt_len)
                         ).astype(np.int32)
    reqs = []
    for i in range(args.requests):
        tail = rng.randint(0, cfg.vocab_size,
                           args.prompt_len - len(shared)).astype(np.int32)
        r = Request(uid=i, prompt=np.concatenate([shared, tail]),
                    max_new_tokens=args.max_new,
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_p=args.top_p,
                                            seed=args.seed + i,
                                            deadline_s=args.deadline_s
                                            or None))
        reqs.append(r)
        engine.submit(r)
    finished = engine.run()
    st = engine.stats()
    print(f"served {st.requests} requests, {st.total_new_tokens} tokens "
          f"in {st.wall_time_s:.2f}s ({st.tokens_per_s:.1f} tok/s, "
          f"mean TTFT {st.mean_ttft_s * 1e3:.0f} ms, "
          f"decode step {st.decode_step_ms:.2f} ms [{engine.attn_impl}], "
          f"{st.prefill_calls} prefill calls / "
          f"{st.prefill_compilations} compiled shapes)")
    if st.preemptions or st.cancelled or st.expired or st.failed:
        print(f"lifecycle: {st.preemptions} preemption(s) "
              f"(mean requeue wait {st.mean_requeue_wait_s * 1e3:.0f} ms), "
              f"{st.cancelled} cancelled, {st.expired} expired, "
              f"{st.failed} failed")
    if engine.paged:
        mem = engine.kv_memory()
        per_dev = (f" ({mem['kv_bytes_peak_per_device']} B/device, "
                   f"{mem['kv_shard_degree']}-way K/V shard)"
                   if mem["kv_shard_degree"] > 1 else "")
        print(f"paged KV: {st.kv_pages_peak}/{st.kv_pages_total} pages peak "
              f"({st.kv_page_util:.0%} util, {st.prefill_chunk_calls} "
              f"prefill chunks), {mem['kv_bytes_peak']} B resident peak vs "
              f"{mem['kv_bytes_contiguous']} B contiguous provisioning"
              + per_dev)
    if config.prefix_cache:
        print(f"prefix cache: {st.prefix_hits} hit(s) / "
              f"{st.prefix_misses} miss(es) ({st.prefix_hit_rate:.0%}), "
              f"{st.prefix_rows_reused} rows reused, "
              f"{st.kv_bytes_saved} B prefill KV skipped, "
              f"{st.kv_pages_cached} page(s) retained, "
              f"{st.prefix_evictions} eviction(s), "
              f"{st.cow_copies} COW page copy(ies); "
              f"TTFT warm {st.mean_ttft_warm_s * 1e3:.0f} ms vs "
              f"cold {st.mean_ttft_cold_s * 1e3:.0f} ms")
    if config.speculative is not None:
        print(f"speculative: {st.spec_rounds} round(s) (k="
              f"{config.speculative.k}), {st.draft_accepted}/"
              f"{st.draft_tokens} drafts accepted "
              f"({st.acceptance_rate:.0%}), "
              f"{st.spec_tokens_per_round:.2f} tokens/stream/verify "
              f"({st.spec_tokens_per_round:.2f}x fewer target dispatches "
              f"than sequential decode), draft time {st.draft_time_s:.2f}s")
    for r in finished[:3]:
        print(f"  req {r.uid}: ttft={r.ttft * 1e3:.0f}ms "
              f"{r.tokens_per_s:.1f} tok/s  {r.generated[:10]}...")


if __name__ == "__main__":
    main()
