"""Serving launcher: batched generation with optional HC-SMoE merging.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --merge-to 4 --requests 6
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--merge-to", type=int, default=0,
                    help="HC-SMoE: merge experts to this count before serving")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--moe-mode", default="ragged")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.merge_to and cfg.moe is not None:
        from repro.core import HCSMoEConfig, run_hcsmoe
        from repro.data import calibration_batches

        calib = calibration_batches(cfg, n_seqs=8, seq_len=128, batch=4)
        t0 = time.time()
        params, _ = run_hcsmoe(model, params, calib,
                               HCSMoEConfig(target_experts=args.merge_to))
        print(f"HC-SMoE merged {cfg.moe.num_experts} -> {args.merge_to} "
              f"experts/layer in {time.time() - t0:.1f}s")

    engine = ServingEngine(model, params, batch_slots=args.slots,
                           max_len=args.prompt_len + args.max_new + 8,
                           moe_mode=args.moe_mode)
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        r = Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
        reqs.append(r)
        engine.submit(r)
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.generated[:10]}...")


if __name__ == "__main__":
    main()
