# Launchers: mesh.py (production meshes), steps.py (sharded step builders),
# dryrun.py (512-chip lower+compile matrix), roofline.py (3-term analysis),
# train.py / serve.py (CLI entry points).
# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
