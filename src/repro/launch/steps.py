"""Step-function builders for the dry-run and launchers: jitted
train / prefill / decode steps with explicit in/out shardings, plus their
ShapeDtypeStruct argument pytrees (zero device allocation)."""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeConfig, input_specs
from repro.models import build_model
from repro.parallel import (
    ParallelConfig, batch_pspecs, cache_pspecs_sized, param_pspecs)
from repro.training.optimizer import OptimizerConfig, OptState, init_opt_state
from repro.training.trainer import make_train_step


def _shard(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _params_sds(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def build_train_step(cfg, shape: ShapeConfig, mesh: Mesh, pc: ParallelConfig,
                     opt_cfg: Optional[OptimizerConfig] = None):
    """Returns (jitted_step, (params_sds, opt_sds, batch_sds))."""
    opt_cfg = opt_cfg if opt_cfg is not None else OptimizerConfig()
    model = build_model(cfg)
    params_sds = _params_sds(model)
    opt_sds = jax.eval_shape(init_opt_state, params_sds)
    batch_sds = input_specs(cfg, shape)

    pspec = param_pspecs(params_sds, pc)
    opt_spec = OptState(step=P(), m=param_pspecs(params_sds, pc), v=param_pspecs(params_sds, pc))
    bspec = batch_pspecs(batch_sds, pc)

    step = make_train_step(model, opt_cfg, pc, grad_accum=1)
    jitted = jax.jit(
        step,
        in_shardings=(_shard(mesh, pspec), _shard(mesh, opt_spec),
                      _shard(mesh, bspec)),
        out_shardings=(_shard(mesh, pspec), _shard(mesh, opt_spec), None),
        donate_argnums=(0, 1),
    )
    return jitted, (params_sds, opt_sds, batch_sds)


def build_prefill_step(cfg, shape: ShapeConfig, mesh: Mesh,
                       pc: ParallelConfig):
    model = build_model(cfg)
    params_sds = _params_sds(model)
    batch_sds = input_specs(cfg, shape)
    tp_size = mesh.shape[pc.tp_axis]

    def prefill(params, batch):
        return model.prefill(params, **batch, cache_max_len=shape.seq_len,
                             moe_mode=pc.moe_mode, unroll=pc.scan_unroll,
                             pc=pc)

    pspec = param_pspecs(params_sds, pc)
    bspec = batch_pspecs(batch_sds, pc)
    out_sds = jax.eval_shape(prefill, params_sds, batch_sds)
    logits_spec = P(pc.dp, None, pc.tp_axis)
    cache_spec = cache_pspecs_sized(cfg, out_sds[1], pc, tp_size)

    jitted = jax.jit(
        prefill,
        in_shardings=(_shard(mesh, pspec), _shard(mesh, bspec)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       _shard(mesh, cache_spec)),
    )
    return jitted, (params_sds, batch_sds)


def build_decode_step(cfg, shape: ShapeConfig, mesh: Mesh, pc: ParallelConfig):
    """One-token decode against a seq_len-deep cache (the decode_* shapes)."""
    model = build_model(cfg)
    params_sds = _params_sds(model)
    specs = input_specs(cfg, shape)
    tokens_sds, cache_sds = specs["tokens"], specs["cache"]
    tp_size = mesh.shape[pc.tp_axis]
    import dataclasses as _dc

    pc_decode = _dc.replace(pc, weight_gather=False)  # weights stay put
    dp_size = 1
    for a in pc.dp_axes:
        dp_size *= mesh.shape[a]
    # context parallelism when the batch can't shard over dp (long_500k B=1):
    # replicate batch, shard the cache LENGTH dim over dp instead.
    ctx_shard = shape.global_batch % dp_size != 0

    def decode(params, tokens, cache):
        return model.decode_step(params, tokens=tokens, cache=cache,
                                 moe_mode=pc.moe_mode, unroll=pc.scan_unroll,
                                 pc=pc_decode)

    pspec = param_pspecs(params_sds, pc)
    cache_spec = cache_pspecs_sized(cfg, cache_sds, pc, tp_size,
                                    ctx_shard=ctx_shard)
    b = None if ctx_shard else pc.dp
    logits_spec = P(b, None, pc.tp_axis)
    tok_spec = P(b, None)

    jitted = jax.jit(
        decode,
        in_shardings=(_shard(mesh, pspec), NamedSharding(mesh, tok_spec),
                      _shard(mesh, cache_spec)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       _shard(mesh, cache_spec)),
        donate_argnums=(2,),
    )
    return jitted, (params_sds, tokens_sds, cache_sds)


def build_step(cfg, shape: ShapeConfig, mesh: Mesh, pc: ParallelConfig):
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, pc)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, pc)
    if shape.kind == "decode":
        return build_decode_step(cfg, shape, mesh, pc)
    raise ValueError(shape.kind)
