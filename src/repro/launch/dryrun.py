import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent end-to-end —
sharding propagation succeeds, the per-device working set fits, and the
collective schedule is materialised — and records ``memory_analysis()`` /
``cost_analysis()`` / parsed collective bytes into JSON for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
Results accumulate in results/dryrun/<arch>__<shape>__<mesh>.json (cells
already present are skipped unless --force).
"""  # noqa: E402

import argparse
import json
import time
import traceback

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config, shape_skip_reason
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    model_flops_analysis, parse_collectives, roofline_terms)
from repro.launch.steps import build_step
from repro.parallel import ParallelConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def parallel_config(mesh_kind: str, *, ep: bool = False,
                    seq_shard: bool = False, remat: str = "full",
                    fsdp: bool = True) -> ParallelConfig:
    dp_axes = ("pod", "data") if mesh_kind == "multi" else ("data",)
    # moe_mode="capacity": static (E, C, d) batched-GEMM dispatch. The XLA
    # ragged_dot path materialises (E, N, d) masks on CPU lowering/backward
    # (19 TB at deepseek scale); capacity-based dispatch is the standard TPU
    # MoE formulation and is what a real deployment would run (the Pallas
    # grouped GEMM being the dropless alternative on real TPUs).
    return ParallelConfig(dp_axes=dp_axes,
                          fsdp_axis="data" if fsdp else None,
                          tp_axis="model", ep=ep, seq_shard=seq_shard,
                          remat=remat, scan_unroll=True, moe_mode="capacity")


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, pc: ParallelConfig = None, tag: str = "",
             merge_to: int = 0) -> dict:
    cfg = get_config(arch)
    if merge_to:
        # HC-SMoE merged-expert serving: the merged model has ``merge_to``
        # live expert slots per layer (router + group_map unchanged; router
        # params are negligible for the roofline) — the paper's deployment
        # configuration (Table 20).
        import dataclasses as _dc0

        cfg = _dc0.replace(cfg, moe=_dc0.replace(cfg.moe,
                                                 num_experts=merge_to))
    shape = SHAPES[shape_name]
    skip = shape_skip_reason(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    pc = pc or parallel_config(mesh_kind)

    import dataclasses as _dc

    from repro.models.flags import cost_accurate_mode

    def _reduced_depth(c, blocks: int):
        changes = {"num_layers":
                   c.first_dense_layers + blocks * len(c.pattern)}
        if c.encoder_layers:
            changes["encoder_layers"] = blocks * len(
                c.encoder_pattern or c.pattern)
        return _dc.replace(c, **changes)

    def _extract_cost(compiled_):
        cost_list = compiled_.cost_analysis()
        cost = (cost_list[0] if isinstance(cost_list, (list, tuple))
                else (cost_list or {}))
        small = {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))
                 and not k.startswith("utilization")}
        coll_ = parse_collectives(compiled_.as_text(), default_group=n_chips)
        return small, coll_

    t0 = time.time()
    # Compile 1 — the FULL-DEPTH production artifact (rolled scans):
    #   memory_analysis (buffer reuse across iterations is explicit).
    # Compiles 2+3 — cost-accurate depth extrapolation: XLA's cost analysis
    #   counts a while-loop body once regardless of trip count, so instead we
    #   compile 1-block and 2-block variants (inner chunk scans unrolled via
    #   cost_accurate_mode) and extrapolate linearly — exact, since blocks
    #   are structurally identical: cost(n) = cost(1) + (n-1)*(cost(2)-cost(1)).
    with mesh:
        pc_mem = _dc.replace(pc, scan_unroll=False)
        jitted_mem, args = build_step(cfg, shape, mesh, pc_mem)
        compiled_mem = jitted_mem.lower(*args).compile()
        t_mem = time.time() - t0
        with cost_accurate_mode():
            pc_cost = _dc.replace(pc, scan_unroll=True)
            costs, colls = [], []
            for blocks in (1, 2):
                cfg_b = _reduced_depth(cfg, blocks)
                jitted_b, args_b = build_step(cfg_b, shape, mesh, pc_cost)
                compiled_b = jitted_b.lower(*args_b).compile()
                c_, coll_ = _extract_cost(compiled_b)
                costs.append(c_)
                colls.append(coll_)
            t_compile = time.time() - t0 - t_mem

    n_rep = cfg.num_blocks

    def _extrap(d1, d2):
        keys = set(d1) | set(d2)
        return {k: d1.get(k, 0.0) + (d2.get(k, 0.0) - d1.get(k, 0.0)) * (n_rep - 1)
                for k in keys}

    cost_small = _extrap(costs[0], costs[1])
    coll = {
        k: (_extrap(colls[0][k], colls[1][k]) if isinstance(colls[0][k], dict)
            else colls[0][k] + (colls[1][k] - colls[0][k]) * (n_rep - 1))
        for k in colls[0]
    }

    mem = compiled_mem.memory_analysis()
    mem_info = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_info[k] = int(getattr(mem, k, 0) or 0)
        mem_info["total_bytes_per_device"] = (
            mem_info.get("argument_size_in_bytes", 0)
            + mem_info.get("output_size_in_bytes", 0)
            + mem_info.get("temp_size_in_bytes", 0)
            - mem_info.get("alias_size_in_bytes", 0))

    t_lower = 0.0
    terms = roofline_terms(cost_small, coll, n_chips=n_chips,
                           cross_pod=(mesh_kind == "multi"))
    from repro.launch.roofline import attach_memory_lb

    attach_memory_lb(terms, cfg, shape, n_chips)
    mfa = model_flops_analysis(cfg, shape, terms["flops_per_chip"], n_chips)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "status": "ok", "n_chips": n_chips,
        "mem_compile_s": round(t_mem, 1),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_info, "cost": cost_small,
        "collectives": {k: (v if not isinstance(v, dict)
                            else {k2: float(v2) for k2, v2 in v.items()})
                        for k, v in coll.items()},
        "roofline": terms, "model_flops": mfa,
        "parallel": {"ep": pc.ep, "seq_shard": pc.seq_shard,
                     "remat": pc.remat, "fsdp": pc.fsdp_axis is not None},
    }


def cell_path(arch, shape_name, mesh_kind, tag=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-paper-models", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--ep", action="store_true", help="expert parallelism")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--merge-to", type=int, default=0,
                    help="roofline the HC-SMoE merged model (r experts)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch else
             list(ALL_ARCHS if args.include_paper_models else ASSIGNED_ARCHS))
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                path = cell_path(arch, shape_name, mesh_kind, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {arch} {shape_name} {mesh_kind}")
                    continue
                print(f"[run] {arch} {shape_name} {mesh_kind} ...", flush=True)
                try:
                    pc = parallel_config(mesh_kind, ep=args.ep,
                                         seq_shard=args.seq_shard,
                                         remat=args.remat,
                                         fsdp=not args.no_fsdp)
                    res = run_cell(arch, shape_name, mesh_kind, pc=pc,
                                   tag=args.tag, merge_to=args.merge_to)
                except Exception as e:  # a failure here is a bug in the system
                    failures += 1
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t={r['step_time_lower_bound_s']:.4f}s"
                             f" mem/dev="
                             f"{res['memory'].get('total_bytes_per_device', 0) / 2**30:.2f}GiB"
                             f" compile={res['compile_s']}s")
                elif status == "error":
                    extra = " " + res["error"][:200]
                print(f"[{status}] {arch} {shape_name} {mesh_kind}{extra}",
                      flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
