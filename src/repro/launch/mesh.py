"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod). Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod'
axis maps to the DCN dimension and carries only gradient all-reduce.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)};"
            " the dry-run entrypoint sets xla_force_host_platform_device_count")
    devs = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    devices = jax.devices()
    if shape is None:
        shape = (len(devices),) + (1,) * (len(axes) - 1)
    n = int(np.prod(shape))
    devs = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_serving_mesh(ep_degree: int | None = None):
    """(data=1, model=ep_degree) mesh for expert-parallel serving.

    Decode batches are small, so serving puts EVERY device on the 'model'
    axis (expert/tensor parallelism) and keeps 'data' trivial; the expert
    dim of each MoE stack then shards ``ep_degree`` ways. Defaults to all
    visible devices."""
    n = len(jax.devices())
    if ep_degree is None:
        ep_degree = n
    if ep_degree > n:
        raise RuntimeError(
            f"ep_degree {ep_degree} exceeds visible devices {n}")
    return make_local_mesh((1, ep_degree))
