"""Training launcher.

CPU-scale end-to-end run (reduced config) or full-scale lowering:

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
      --steps 50 --reduced --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.data import TokenStream
from repro.models import build_model
from repro.parallel import ParallelConfig
from repro.training import OptimizerConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--moe-mode", default="ragged")
    ap.add_argument("--heartbeat", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    stream = TokenStream(cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    oc = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
                         total_steps=args.steps)
    tc = TrainConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, log_every=max(1, args.steps // 20),
                     heartbeat_path=args.heartbeat)
    pc = ParallelConfig(remat="none" if args.reduced else "full",
                        moe_mode=args.moe_mode)
    params, _, log = train(model, stream, oc, tc, pc)
    for entry in log:
        print(f"step {entry['step']:5d}  loss {entry['loss']:.4f}  "
              f"ce {entry.get('ce', 0):.4f}  lr {entry.get('lr', 0):.2e}")
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
