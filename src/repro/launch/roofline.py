"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
  collective = wire_bytes_per_chip / link_bw          (~50 GB/s/link ICI)

``cost_analysis()`` supplies per-chip FLOPs / bytes (the compiled module is
the SPMD-partitioned per-device program). Collective bytes are NOT in
cost_analysis: we parse the optimized HLO text, sum result-shape bytes per
collective op, and convert to wire bytes with ring formulas using the parsed
replica-group size.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip effective)
DCN_BW = 6.25e9              # bytes/s per chip across pods (~50 Gbit)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def parse_collectives(hlo_text: str, default_group: int) -> Dict:
    """Sum collective buffer + estimated wire bytes per device."""
    per_op = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) ([\w\-]+)\(", stripped)
        if not m:
            continue
        type_str, op = m.groups()
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        nbytes = _shape_bytes(type_str)
        g = max(2, _group_size(stripped, default_group))
        if base == "all-reduce":
            w = 2.0 * nbytes * (g - 1) / g
        elif base in ("all-gather", "reduce-scatter", "all-to-all"):
            w = nbytes * (g - 1) / g
        else:  # collective-permute
            w = nbytes
        per_op[base] += nbytes
        wire[base] += w
        count[base] += 1
    return {"buffer_bytes": per_op, "wire_bytes": wire, "counts": count,
            "total_wire_bytes": sum(wire.values())}


def analytic_memory_lb_bytes(cfg, shape, n_chips: int) -> float:
    """Analytic lower bound on per-chip HBM traffic per step: parameter
    reads (x3 for train: fwd, bwd, update incl. f32 moments) + activation
    residual traffic + KV-cache reads for decode. The HLO 'bytes accessed'
    metric is an upper bound inflated by CPU-backend fusion granularity;
    the truth on TPU lies between the two (recorded both in §Roofline)."""
    total, active = cfg.param_counts()
    param_bytes = total * 2 / n_chips  # bf16
    if shape.kind == "train":
        tokens_per_chip = shape.seq_len * shape.global_batch / n_chips
        acts = tokens_per_chip * cfg.d_model * 2 * cfg.num_layers * 3
        opt = total * 8 / n_chips  # f32 m+v read+write amortised
        return 3 * param_bytes + opt + acts
    if shape.kind == "prefill":
        tokens_per_chip = shape.seq_len * shape.global_batch / n_chips
        acts = tokens_per_chip * cfg.d_model * 2 * cfg.num_layers
        return param_bytes + acts
    # decode: all live params + the whole cache cross HBM once per token
    cache_bytes = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer in ("attn", "attn_local", "attn_global"):
            w = min(cfg.sliding_window or shape.seq_len, shape.seq_len)
            cache_bytes += 2 * w * cfg.kv_dim * 2
        elif spec.mixer == "mla":
            cache_bytes += shape.seq_len * (
                cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * 2
    cache_bytes *= shape.global_batch / n_chips
    return param_bytes + cache_bytes


def roofline_terms(cost: Dict, collectives: Dict, *, n_chips: int,
                   cross_pod: bool = False) -> Dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    wire = float(collectives["total_wire_bytes"])
    link_bw = DCN_BW if cross_pod else ICI_BW
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": wire / ICI_BW,
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "wire_bytes_per_chip": wire,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["step_time_lower_bound_s"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms


def attach_memory_lb(terms: Dict, cfg, shape, n_chips: int) -> Dict:
    lb = analytic_memory_lb_bytes(cfg, shape, n_chips)
    terms["memory_lb_s"] = lb / HBM_BW
    terms["memory_lb_bytes"] = lb
    return terms


def model_flops_analysis(cfg, shape, hlo_flops_per_chip: float,
                         n_chips: int) -> Dict:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (fwd) and the
    useful-compute ratio vs compiled HLO FLOPs."""
    total, active = cfg.param_counts()
    # enc-dec: the seq budget is split src/tgt, and each side only runs its
    # own half of the params — approximate with tokens = seq/2 against the
    # full param set (exact split recorded in DESIGN.md)
    seq_eff = shape.seq_len // 2 if cfg.family == "encdec" else shape.seq_len
    if shape.kind == "train":
        tokens = seq_eff * shape.global_batch
        mf = 6.0 * active * tokens
    elif shape.kind == "prefill":
        tokens = seq_eff * shape.global_batch
        mf = 2.0 * active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mf = 2.0 * active * tokens
    hlo_total = hlo_flops_per_chip * n_chips
    return {
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
        "params_total": total,
        "params_active": active,
    }


def mfu(cfg, shape, step_time_s: float, n_chips: int) -> float:
    mf = model_flops_analysis(cfg, shape, 0.0, 1)["model_flops"]
    return mf / (step_time_s * n_chips * PEAK_FLOPS) if step_time_s else 0.0
