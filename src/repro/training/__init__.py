from repro.training.optimizer import (  # noqa: F401
    OptimizerConfig, OptState, apply_updates, init_opt_state, lr_at)
from repro.training.trainer import (  # noqa: F401
    TrainConfig, Watchdog, jit_train_step, make_ddp_train_step,
    make_train_step, train)
