"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Integer leaves (``group_map``) and additive masks are held constant; norm /
bias / router-mask leaves are excluded from weight decay. Moment tensors are
f32 regardless of param dtype (mixed-precision training convention). Under
pjit, moments inherit the parameter PartitionSpecs, which is exactly
ZeRO-style sharded optimizer state on the FSDP axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def _trainable(leaf) -> bool:
    return jnp.issubdtype(jnp.asarray(leaf).dtype
                          if not hasattr(leaf, "dtype") else leaf.dtype,
                          jnp.floating)


def _decay_mask(path) -> bool:
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    last = names[-1] if names else ""
    if last.startswith("ln") or "norm" in last or last in (
            "b", "b_gates", "conv_b", "dt_proj_b", "router_mask", "D"):
        return False
    return True


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if _trainable(p) else None,
        params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros)


def _global_norm(grads):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)
              if g is not None and _trainable(g)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, state: OptState, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = lr_at(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]

    new_p, new_m, new_v = [], [], []
    for p, g, m, v, path in zip(flat_p, flat_g, flat_m, flat_v, paths):
        if not _trainable(p) or g is None or m is None:
            new_p.append(p)
            new_m.append(m)
            new_v.append(v)
            continue
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_state = OptState(step=step,
                         m=jax.tree_util.tree_unflatten(treedef, new_m),
                         v=jax.tree_util.tree_unflatten(treedef, new_v))
    return params, new_state, {"lr": lr, "grad_norm": gnorm}
