"""Training loop with fault tolerance, straggler mitigation hooks, gradient
accumulation, and two distribution modes:

  * ``pjit``  — FSDP×TP(×pod-DP) GSPMD sharding from ParallelConfig specs;
                the production path (what the dry-run lowers).
  * ``ddp``   — shard_map pure data parallelism with optional int8
                error-feedback gradient compression on the all-reduce
                (the cross-pod/DCN story, exercised in multi-device tests).

Fault tolerance: atomic keep-k checkpoints every ``ckpt_every`` steps
(params + optimizer + data step), exact resume, and a heartbeat file a
launcher-level watchdog uses to detect hung/straggling workers and restart
from the latest checkpoint (see ``Watchdog``).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import shard_map_compat

from repro.checkpoint import CheckpointManager
from repro.parallel import ParallelConfig, batch_pspecs, param_pspecs
from repro.parallel.compression import compressed_psum_grads
from repro.training.optimizer import (
    OptimizerConfig, OptState, apply_updates, init_opt_state)


@dataclass
class TrainConfig:
    total_steps: int = 100
    grad_accum: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    heartbeat_path: Optional[str] = None
    step_deadline_s: Optional[float] = None  # straggler deadline (watchdog)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_train_step(model, opt_cfg: OptimizerConfig, pc: ParallelConfig,
                    *, grad_accum: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    Microbatch gradient accumulation happens inside a lax.scan so the
    lowered HLO is accumulation-steps-independent.
    """

    def loss_fn(params, micro):
        loss, metrics = model.train_loss(params, micro, moe_mode=pc.moe_mode,
                                         remat=pc.remat,
                                         unroll=pc.scan_unroll,
                                         pc=pc if pc.fsdp_axis else None)
        return loss, metrics

    def step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(params, batch)
        else:
            def micro_step(acc, micro):
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True, allow_int=True)(params, micro)
                acc = jax.tree.map(
                    lambda a, b: None if a is None
                    else a + b.astype(jnp.float32), acc, g,
                    is_leaf=lambda x: x is None)
                return acc, (l, m)

            zeros = jax.tree.map(_zeros_like_f32, params)
            micros = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            grads, (losses, metricses) = jax.lax.scan(micro_step, zeros, micros)
            grads = jax.tree.map(
                lambda g: g / grad_accum if g is not None else None, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return step


def _f32_or_none(g):
    return None if g is None else g.astype(jnp.float32)


def _zeros_like_f32(p):
    if jnp.issubdtype(p.dtype, jnp.floating):
        return jnp.zeros(p.shape, jnp.float32)
    return None


def jit_train_step(model, opt_cfg, pc: ParallelConfig, mesh: Mesh,
                   params_shape, batch_shape, *, grad_accum: int = 1):
    """pjit-compiled train step with explicit in/out shardings."""
    step = make_train_step(model, opt_cfg, pc, grad_accum=grad_accum)
    pspec = param_pspecs(params_shape, pc)
    opt_shape = jax.eval_shape(init_opt_state, params_shape)
    opt_spec = OptState(step=P(), m=pspec, v=pspec)
    bspec = batch_pspecs(batch_shape, pc)

    def shard(tree_spec):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec,
                            is_leaf=lambda x: isinstance(x, P))

    return jax.jit(
        step,
        in_shardings=(shard(pspec), shard(opt_spec), shard(bspec)),
        out_shardings=(shard(pspec), shard(opt_spec), None),
        donate_argnums=(0, 1),
    )


def make_ddp_train_step(model, opt_cfg, pc: ParallelConfig, mesh: Mesh,
                        axis: str = "data", *, compress: bool = False):
    """shard_map pure-DP step: per-device grads -> (compressed) psum ->
    identical update everywhere. Returns step(params, opt, err, batch)."""

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch, moe_mode=pc.moe_mode,
                                         remat=pc.remat)
        return loss, metrics

    @partial(shard_map_compat, mesh=mesh,
             in_specs=(P(), OptState(step=P(), m=P(), v=P()), P(), P(axis)),
             out_specs=(P(), OptState(step=P(), m=P(), v=P()), P(), P()),
             check_vma=False)
    def step(params, opt_state, err, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True, allow_int=True)(params, batch)
        grads = jax.tree.map(
            lambda g, p: g if jnp.issubdtype(p.dtype, jnp.floating) else None,
            grads, params)
        if compress:
            grads, err = compressed_psum_grads(grads, err, axis)
        else:
            grads = jax.tree.map(
                lambda g: None if g is None
                else jax.lax.pmean(g.astype(jnp.float32), axis), grads)
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, axis), metrics)
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, err, {**metrics, **om, "loss": loss}

    return jax.jit(step)


def init_ddp_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.floating) else None, params)


# ---------------------------------------------------------------------------
# Fault-tolerant loop + watchdog
# ---------------------------------------------------------------------------


class Watchdog:
    """Launcher-side straggler/failure detector: a worker writes a heartbeat
    (step + wall time) after every step; the watchdog flags workers whose
    heartbeat age exceeds the step deadline so the launcher can restart them
    from the latest checkpoint (restart-from-ckpt is the mitigation — the
    loop below is resume-exact)."""

    def __init__(self, path: str, deadline_s: float):
        self.path = path
        self.deadline_s = deadline_s

    def beat(self, step: int):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    def is_straggling(self, now: Optional[float] = None) -> bool:
        try:
            with open(self.path) as f:
                hb = json.load(f)
        except FileNotFoundError:
            return False
        return ((now or time.time()) - hb["time"]) > self.deadline_s


def train(model, stream, opt_cfg: OptimizerConfig, tc: TrainConfig,
          pc: ParallelConfig, mesh: Optional[Mesh] = None,
          *, params=None, fail_at_step: Optional[int] = None,
          step_fn=None):
    """Run (or resume) training. ``fail_at_step`` raises mid-run to exercise
    the checkpoint/restart path in tests. Returns (params, opt_state, log)."""
    mgr = CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep)
    watchdog = (Watchdog(tc.heartbeat_path, tc.step_deadline_s or 60.0)
                if tc.heartbeat_path else None)

    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    start_step = 0
    if mgr.latest_step() is not None:
        restored, start_step = mgr.restore(
            {"params": params, "opt": opt_state, "meta": {}})
        params, opt_state = restored["params"], restored["opt"]

    if step_fn is None:
        step_fn = jax.jit(make_train_step(model, opt_cfg, pc,
                                          grad_accum=tc.grad_accum))

    log = []
    for step in range(start_step, tc.total_steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"simulated failure at step {step}")
        batch = jax.tree.map(jnp.asarray, stream.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if watchdog:
            watchdog.beat(step)
        if step % tc.log_every == 0 or step == tc.total_steps - 1:
            log.append({"step": step,
                        **{k: float(v) for k, v in metrics.items()}})
        if (step + 1) % tc.ckpt_every == 0 or step == tc.total_steps - 1:
            mgr.save(step + 1, {"params": params, "opt": opt_state,
                                "meta": {"data_step": step + 1}})
    return params, opt_state, log
