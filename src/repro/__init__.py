"""repro: production-grade JAX framework reproducing HC-SMoE (ICML 2025) —
retraining-free merging of sparse-MoE experts via hierarchical clustering —
with a 10-architecture model zoo, FSDP×TP(×pod) distribution, Pallas TPU
kernels, fault-tolerant training, batched serving, and a 512-chip dry-run +
roofline harness."""

__version__ = "1.0.0"
