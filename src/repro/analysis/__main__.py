"""CLI for the static-analysis layer.

``python -m repro.analysis kernels``
    Run the Pallas kernel contract battery (repro.analysis.kernel_verify):
    every pallas_call site is launched in interpret mode under a capture
    hook and its BlockSpec index maps are exhaustively evaluated over the
    full grid. Needs jax. Exit 1 on any finding.

``python -m repro.analysis lint <paths...>``
    Run the AST JAX-hazard linter (repro.analysis.lint) over files or
    directories. Stdlib-only — works without jax installed, so the CI lint
    job can run it next to ruff. Exit 1 on any finding.
"""
from __future__ import annotations

import argparse
import sys


def _cmd_kernels() -> int:
    from repro.analysis.kernel_verify import verify_all

    results = verify_all()
    n_findings = 0
    for name, findings in results.items():
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(f"[kernel-verify] {name}: {status}")
        for f in findings:
            print(f"  {f}")
        n_findings += len(findings)
    print(f"[kernel-verify] {len(results)} cases, {n_findings} finding(s)")
    return 1 if n_findings else 0


def _cmd_lint(paths) -> int:
    from repro.analysis.lint import lint_paths

    findings = lint_paths(paths)
    for f in findings:
        print(f)
    print(f"[lint] {len(findings)} finding(s)")
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Pallas kernel contract verifier + JAX-hazard linter "
                    "(rule catalogue: docs/static_analysis.md)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("kernels",
                   help="verify every pallas_call site's BlockSpec "
                        "contracts over the full grid (needs jax)")
    lint = sub.add_parser("lint",
                          help="AST JAX-hazard linter (stdlib-only)")
    lint.add_argument("paths", nargs="+",
                      help="files or directories to lint")
    args = ap.parse_args(argv)
    if args.cmd == "kernels":
        return _cmd_kernels()
    return _cmd_lint(args.paths)


if __name__ == "__main__":
    sys.exit(main())
