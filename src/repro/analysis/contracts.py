"""Runtime shape/dtype contracts for hot interfaces.

``@checked(q="B H hd", pos="B:int", ret="B H hd")`` asserts the shapes of
the named arguments (and the return value via the reserved key ``ret``)
when the environment variable ``REPRO_CONTRACTS`` is truthy — tests/CI set
it — and compiles to the *identity decorator* otherwise: with contracts
off, ``checked`` returns the function object unchanged, so production call
paths pay nothing, not even a wrapper frame.

Spec mini-language (stdlib-only; works on numpy arrays AND jax tracers,
because only static metadata — ``.shape`` / ``.dtype`` — is read, so the
checks run at trace time under jit):

- ``"B W K hd"``  — rank-4 array; each named dim unifies across all specs
  of one call (the ``B`` of ``q`` must equal the ``B`` of ``pos``).
- ``"B 128"``     — integer literals pin a dim exactly.
- ``"B _"``       — ``_`` matches any size without binding a name.
- ``"B W:int"``   — a trailing ``:int`` / ``:float`` / ``:bool`` marker
  checks the dtype kind.
- a callable      — ``spec(value, dims)`` with the unification env so far;
  return ``False`` (or raise) to reject, anything else passes.

Violations raise :class:`ContractError` naming the function, argument, and
the dim that failed to unify.
"""
from __future__ import annotations

import functools
import inspect
import os
from typing import Any, Callable, Dict, Union

__all__ = ["ContractError", "checked", "contracts_enabled"]


class ContractError(TypeError):
    """A @checked shape/dtype contract was violated."""


_ENABLED = os.environ.get("REPRO_CONTRACTS", "").lower() not in (
    "", "0", "false", "off")


def contracts_enabled() -> bool:
    """Whether @checked was armed at import time (REPRO_CONTRACTS)."""
    return _ENABLED


def _dtype_kind(value: Any) -> str:
    name = str(getattr(value, "dtype", ""))
    if name.startswith(("int", "uint")):
        return "int"
    if name.startswith(("float", "bfloat")):
        return "float"
    if name == "bool":
        return "bool"
    return name


def _check_spec(fname: str, arg: str, value: Any, spec: str,
                dims: Dict[str, int]) -> None:
    spec = spec.strip()
    kind = None
    if ":" in spec:
        spec, kind = (s.strip() for s in spec.rsplit(":", 1))
    shape = getattr(value, "shape", None)
    if shape is None:
        raise ContractError(
            f"{fname}: {arg} expected an array with shape ({spec}), got "
            f"{type(value).__name__}")
    tokens = spec.split()
    if len(shape) != len(tokens):
        raise ContractError(
            f"{fname}: {arg} expected rank {len(tokens)} ({spec}), got "
            f"shape {tuple(shape)}")
    for tok, size in zip(tokens, shape):
        size = int(size)
        if tok == "_":
            continue
        if tok.isdigit():
            if size != int(tok):
                raise ContractError(
                    f"{fname}: {arg} dim {tok} != {size} "
                    f"(shape {tuple(shape)})")
            continue
        bound = dims.setdefault(tok, size)
        if bound != size:
            raise ContractError(
                f"{fname}: {arg} dim {tok}={size} conflicts with "
                f"{tok}={bound} bound by an earlier argument "
                f"(shape {tuple(shape)})")
    if kind is not None and _dtype_kind(value) != kind:
        raise ContractError(
            f"{fname}: {arg} expected {kind} dtype, got "
            f"{getattr(value, 'dtype', None)}")


def _check(fname: str, arg: str, value: Any,
           spec: Union[str, Callable[..., Any]],
           dims: Dict[str, int]) -> None:
    if callable(spec):
        try:
            ok = spec(value, dims)
        except ContractError:
            raise
        except Exception as e:
            raise ContractError(f"{fname}: {arg} predicate raised "
                                f"{e!r}") from e
        if ok is False:
            raise ContractError(
                f"{fname}: {arg} failed contract predicate "
                f"{getattr(spec, '__name__', spec)!r}")
        return
    _check_spec(fname, arg, value, spec, dims)


def checked(**specs: Union[str, Callable[..., Any]]):
    """Shape/dtype contract decorator; ``ret=`` specs the return value.

    Identity (returns ``fn`` itself) unless REPRO_CONTRACTS was set at
    import time.
    """
    if not _ENABLED:
        return lambda fn: fn

    ret_spec = specs.pop("ret", None)

    def deco(fn):
        sig = inspect.signature(fn)
        unknown = set(specs) - set(sig.parameters)
        if unknown:
            raise ContractError(
                f"{fn.__qualname__}: @checked names unknown parameters "
                f"{sorted(unknown)}")

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            bound = sig.bind(*args, **kwargs)
            dims: Dict[str, int] = {}
            for name, spec in specs.items():
                if name in bound.arguments:
                    _check(fn.__qualname__, name, bound.arguments[name],
                           spec, dims)
            out = fn(*args, **kwargs)
            if ret_spec is not None:
                _check(fn.__qualname__, "return", out, ret_spec, dims)
            return out

        return wrapper

    return deco
