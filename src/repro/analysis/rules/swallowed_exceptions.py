"""RPR008: swallowed exceptions in library code.

A bare ``except:`` or an ``except Exception:`` / ``except BaseException:``
whose body does nothing (only ``pass`` / ``...``) hides real failures: a
PageExhausted that should trigger preemption, a poisoned-logits guard, a
splice error that must fail the batch — all vanish into a no-op handler.
The serving engine's robustness contract depends on errors PROPAGATING to
the layer that owns the recovery decision (see docs/serving_lifecycle.md),
so library code may only catch what it handles.

Flagged:
* ``except:`` (bare) — anywhere in library code, regardless of body: it
  also traps KeyboardInterrupt/SystemExit.
* ``except Exception:`` / ``except BaseException:`` (incl. aliased via
  ``as e``) whose body is only ``pass``/``...`` — the classic silent
  swallow.

Not flagged: narrow handlers (``except PageExhausted:``), broad handlers
that DO something (log, re-raise, return a fallback), and anything outside
library code (CLI entry points in ``repro/launch`` legitimately catch-all
at top level to format user-facing errors).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint import FileContext, LintFinding, Rule, in_library

_BROAD = {"Exception", "BaseException"}


def _is_noop_body(body) -> bool:
    """True when the handler body does nothing: only pass / bare `...`."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) \
                and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is Ellipsis:
            continue
        return False
    return True


def _broad_name(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if isinstance(t, ast.Name):
        return t.id in _BROAD
    if isinstance(t, ast.Attribute):     # builtins.Exception
        return t.attr in _BROAD
    return False


class SwallowedExceptionRule(Rule):
    """RPR008: bare/broad except that silently discards the error."""

    id = "RPR008"
    name = "swallowed-exception"

    def applies_to(self, path: str) -> bool:
        return in_library(path)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self.finding(
                    ctx, node,
                    "bare `except:` traps everything incl. "
                    "KeyboardInterrupt/SystemExit — catch the specific "
                    "exception the code can actually handle")
            elif _broad_name(node) and _is_noop_body(node.body):
                yield self.finding(
                    ctx, node,
                    "`except Exception: pass` silently swallows failures "
                    "the caller needs (preemption, quarantine, abort) — "
                    "handle it, log it, or let it propagate")
