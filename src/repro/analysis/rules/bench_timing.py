"""RPR005: timed regions in benchmarks/ must synchronise before reading the
clock. jax dispatch is async — ``t0 = time(); f(x); dt = time() - t0``
measures dispatch latency, not compute: the result must pass through
``jax.block_until_ready`` (or ``.block_until_ready()``) inside the region.
Host-only timing (aggregating wall clock around subprocesses or whole
benchmark modules) is legitimate — suppress with ``# noqa: RPR005`` and say
why in a comment.
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from repro.analysis.lint import FileContext, LintFinding, Rule, in_benchmarks
from repro.analysis.rules._shared import _FuncDef


def _time_calls(node: ast.AST) -> List[ast.Call]:
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            name = (f.attr if isinstance(f, ast.Attribute)
                    else f.id if isinstance(f, ast.Name) else None)
            if name in ("time", "perf_counter", "monotonic"):
                # time.time() / time.perf_counter() / bare perf_counter()
                if isinstance(f, ast.Name) and name == "time":
                    continue  # `time(...)` bare call: not the module clock
                out.append(n)
    return out


def _has_sync(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr == "block_until_ready":
            return True
        if isinstance(n, ast.Name) and n.id == "block_until_ready":
            return True
    return False


class BenchTimingRule(Rule):
    """RPR005: a function in benchmarks/ that reads the clock twice (a timed
    region) without any block_until_ready call times async dispatch, not
    the kernel."""

    id = "RPR005"
    name = "bench-unsynced-timing"

    def applies_to(self, path: str) -> bool:
        return in_benchmarks(path)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        funcs = [n for n in ast.walk(tree) if isinstance(n, _FuncDef)]
        for fn in funcs:
            # exclude nested defs' clocks: they are reported on their own
            nested = {id(sub) for f2 in ast.walk(fn) if isinstance(f2, _FuncDef)
                      and f2 is not fn for sub in ast.walk(f2)}
            calls = [c for c in _time_calls(fn) if id(c) not in nested]
            if len(calls) >= 2 and not _has_sync(fn):
                yield self.finding(
                    ctx, calls[1],
                    f"timed region in {fn.name}() never calls "
                    "block_until_ready — with async dispatch this measures "
                    "enqueue time, not compute; materialise the result "
                    "before the closing timestamp (host-only wall-clock "
                    "timing: suppress with `# noqa: RPR005` + a comment)")
