"""Tracing hazards: RPR001 (Python control flow on traced values inside
jit/shard_map/Pallas bodies), RPR002 (jnp arrays built at module scope —
closure-capture / retrace hazard), RPR003 (host casts of traced values).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint import FileContext, LintFinding, Rule, in_library
from repro.analysis.rules._shared import (
    _FuncDef, _identifiers, taint, traced_scopes, unsanitized_uses)

_JNP_CONSTRUCTORS = {
    "array", "asarray", "zeros", "ones", "full", "arange", "linspace",
    "eye", "zeros_like", "ones_like", "full_like", "identity", "tri",
    "PRNGKey",
}


class TracedBranchRule(Rule):
    """RPR001: `if`/`while`/ternary on a traced value inside a traced scope
    either raises ConcretizationTypeError or silently specialises the
    compiled program to one branch. Use jnp.where / lax.cond / lax.select,
    or hoist the decision to a static (keyword-only, functools.partial-bound)
    parameter."""

    id = "RPR001"
    name = "traced-branch"

    def applies_to(self, path: str) -> bool:
        return in_library(path)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        for fn, kind in traced_scopes(tree):
            tainted = taint(fn, kind)
            if not tainted:
                continue
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, _FuncDef):
                        # nested defs get their own scope entry if traced
                        continue
                    if isinstance(node, (ast.If, ast.While)):
                        test = node.test
                    elif isinstance(node, ast.IfExp):
                        test = node.test
                    elif isinstance(node, ast.Assert):
                        test = node.test
                    else:
                        continue
                    for use in unsanitized_uses(test, tainted):
                        yield self.finding(
                            ctx, use,
                            f"Python control flow on {use.id!r}, which is "
                            f"traced inside this {kind} scope — use "
                            "jnp.where/lax.cond or bind it statically via "
                            "functools.partial")
                        break  # one finding per branch site


class ModuleLevelJnpConstRule(Rule):
    """RPR002: a jnp array created at import time becomes a baked-in
    closure constant of every jitted function that touches it — it pins a
    device at import, defeats donation, and any identity-based cache keys
    retrace per process. Build arrays inside the traced function (XLA
    folds them) or keep module constants as numpy."""

    id = "RPR002"
    name = "module-jnp-constant"

    def applies_to(self, path: str) -> bool:
        return in_library(path)

    def _walk_static(self, node: ast.AST) -> Iterator[ast.AST]:
        """Walk without descending into function/lambda bodies (those run
        later, not at import)."""
        stack = [node]
        while stack:
            n = stack.pop()
            yield n
            for child in ast.iter_child_nodes(n):
                if isinstance(child, (*_FuncDef, ast.Lambda)):
                    # defaults DO evaluate at import time
                    if isinstance(child, _FuncDef):
                        stack.extend(child.args.defaults)
                        stack.extend(d for d in child.args.kw_defaults if d)
                        stack.extend(child.decorator_list)
                    continue
                stack.append(child)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        for node in self._walk_static(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not (isinstance(f, ast.Attribute)
                    and f.attr in _JNP_CONSTRUCTORS):
                continue
            ids = _identifiers(f)
            if "jnp" in ids or ("jax" in ids
                                and ids & {"numpy", "random"}):
                yield self.finding(
                    ctx, node,
                    f"{f.attr}(...) on jnp at module scope builds a device "
                    "array at import — retrace/closure-constant hazard; "
                    "use numpy here or build it inside the function")


class TracedHostCastRule(Rule):
    """RPR003: `.item()` / int()/float()/bool() on a traced value forces a
    host sync at best and a ConcretizationTypeError inside jit at worst."""

    id = "RPR003"
    name = "traced-host-cast"

    def applies_to(self, path: str) -> bool:
        return in_library(path)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        for fn, kind in traced_scopes(tree):
            tainted = taint(fn, kind)
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    if isinstance(node.func, ast.Attribute) \
                            and node.func.attr == "item":
                        yield self.finding(
                            ctx, node,
                            ".item() inside a traced scope concretises a "
                            "tracer — return the array and convert outside "
                            "the jit boundary")
                        continue
                    if isinstance(node.func, ast.Name) \
                            and node.func.id in ("int", "float", "bool") \
                            and len(node.args) == 1 \
                            and any(unsanitized_uses(node.args[0], tainted)):
                        yield self.finding(
                            ctx, node,
                            f"{node.func.id}() on a traced value inside a "
                            f"{kind} scope raises ConcretizationTypeError — "
                            "keep it an array or hoist the cast out of the "
                            "traced region")
