"""Shared AST machinery for the hazard rules: traced-scope discovery and a
conservative value-taint pass.

A *traced scope* is a function whose body runs under a jax trace: decorated
with (or wrapped by / passed to) ``jit``/``pjit``/``shard_map``/
``shard_map_compat``, or a Pallas kernel handed to ``pallas_call``. Inside
such scopes, Python-level control flow on traced values either raises a
``ConcretizationTypeError`` or — worse — silently bakes one branch into the
compiled program; the rules in :mod:`jax_hazards` flag those sites.

Taint seeding differs by scope kind: in jit/shard_map scopes the function
parameters themselves are tracers, while in Pallas kernels the parameters
are Refs (static) and only their *reads* (``ref[...]``), ``pl.program_id``
results, and ``jnp`` expressions are traced. Keyword-only parameters are
treated as static in both: the repo idiom binds them via
``functools.partial`` with Python constants (tile sizes, windows, flags),
which is exactly the static-configuration channel.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

TRACE_WRAPPERS = {"jit", "pjit", "shard_map", "shard_map_compat"}
PALLAS_WRAPPERS = {"pallas_call"}

# attribute reads that are static at trace time even on a tracer
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval",
                "itemsize"}
# host functions whose result on a tracer-adjacent value is static/harmless
SAFE_FUNCS = {"len", "isinstance", "hasattr", "getattr", "type", "id",
              "callable"}

_FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)


def _identifiers(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def traced_scopes(tree: ast.AST) -> List[Tuple[ast.AST, str]]:
    """All (function node, kind) pairs whose bodies run under a jax trace;
    kind is "jit" or "pallas"."""
    scopes: List[Tuple[ast.AST, str]] = []
    seen: Set[ast.AST] = set()
    defs_by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, _FuncDef):
            defs_by_name.setdefault(node.name, []).append(node)

    def add(fn: ast.AST, kind: str):
        if fn not in seen:
            seen.add(fn)
            scopes.append((fn, kind))

    for node in ast.walk(tree):
        # decorator form: @jax.jit, @jit, @partial(jax.jit, ...),
        # @partial(shard_map_compat, mesh=...)
        if isinstance(node, _FuncDef):
            for dec in node.decorator_list:
                ids = _identifiers(dec)
                if ids & TRACE_WRAPPERS:
                    add(node, "jit")
                elif ids & PALLAS_WRAPPERS:
                    add(node, "pallas")
        # call form: jax.jit(step), shard_map_compat(fn, ...),
        # pl.pallas_call(kernel, ...), functools.partial(_kernel, ...)
        # where the wrapped function is named locally
        if isinstance(node, ast.Call):
            ids = _identifiers(node.func)
            kind = ("jit" if ids & TRACE_WRAPPERS
                    else "pallas" if ids & PALLAS_WRAPPERS else None)
            if kind is None:
                continue
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    for fn in defs_by_name.get(arg.id, ()):
                        add(fn, kind)
                elif isinstance(arg, ast.Lambda):
                    add(arg, kind)
                elif (isinstance(arg, ast.Call)
                      and "partial" in _identifiers(arg.func)):
                    # pallas_call(functools.partial(_kernel, ...), ...)
                    for sub in arg.args:
                        if isinstance(sub, ast.Name):
                            for fn in defs_by_name.get(sub.id, ()):
                                add(fn, kind)
    return scopes


def _is_traced_expr(node: ast.AST, tainted: Set[str]) -> bool:
    """Does this expression (conservatively) produce a traced value?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id in tainted:
            return True
        if isinstance(n, ast.Call):
            ids = _identifiers(n.func)
            if ids & {"jnp", "lax", "program_id", "dot", "einsum"}:
                return True
            if "jax" in ids and not ids & SAFE_FUNCS:
                return True
    return False


def taint(fn: ast.AST, kind: str) -> Set[str]:
    """Names (conservatively) bound to traced values inside ``fn``."""
    tainted: Set[str] = set()
    args = fn.args
    if kind == "jit":
        # positional params are tracers; keyword-only params are the
        # functools.partial static-config channel (tile sizes, flags)
        tainted |= {a.arg for a in args.args + args.posonlyargs}
        if args.vararg:
            tainted.add(args.vararg.arg)
    else:
        # pallas: params are Refs — only their reads are traced; seed with
        # nothing and let subscript loads / program_id propagate below
        pass
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    ref_params = {a.arg for a in args.args + args.posonlyargs}
    for _ in range(2):  # two passes: forward refs through simple reorders
        for stmt in body:
            for n in ast.walk(stmt):
                traced = False
                if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    val = n.value
                    if val is None:
                        continue
                    traced = _is_traced_expr(val, tainted)
                    if kind == "pallas" and not traced:
                        # x = ref[...] reads a traced value out of a Ref
                        traced = any(
                            isinstance(s, ast.Subscript)
                            and isinstance(s.value, ast.Name)
                            and s.value.id in (ref_params | tainted)
                            for s in ast.walk(val))
                    if not traced:
                        continue
                    targets = (n.targets if isinstance(n, ast.Assign)
                               else [n.target])
                    for t in targets:
                        for leaf in ast.walk(t):
                            if isinstance(leaf, ast.Name):
                                tainted.add(leaf.id)
    return tainted


def unsanitized_uses(test: ast.AST, tainted: Set[str]) -> Iterator[ast.Name]:
    """Tainted Name loads in a branch test that are NOT wrapped in a
    static-safe construct (.shape/.ndim/.dtype, len()/isinstance(),
    ``is None`` checks)."""
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(test):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(test):
        if not (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in tainted):
            continue
        cur, safe = node, False
        while cur in parents:
            parent = parents[cur]
            if isinstance(parent, ast.Attribute) \
                    and parent.attr in STATIC_ATTRS:
                safe = True
                break
            if isinstance(parent, ast.Call) and cur is not parent.func:
                ids = _identifiers(parent.func)
                if ids & SAFE_FUNCS:
                    safe = True
                    break
            if isinstance(parent, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot))
                    for op in parent.ops):
                safe = True
                break
            cur = parent
        if not safe:
            yield node
