"""RPR004: collective axis names must exist on a declared mesh.

``jax.lax.psum(x, "modle")`` fails only at run time, inside a shard_map on
real hardware — CPU unit tests that don't enter the collective never see
it. The allowlist of axis names is scraped (AST, no imports) from the two
modules that declare meshes: ``repro/parallel/sharding.py``
(``ParallelConfig`` defaults) and ``repro/launch/mesh.py`` (the mesh axes
tuples), so adding an axis there automatically teaches the linter.
"""
from __future__ import annotations

import ast
import functools
from pathlib import Path
from typing import FrozenSet, Iterator, Optional

from repro.analysis.lint import FileContext, LintFinding, Rule, norm_path
from repro.analysis.rules._shared import _identifiers

# axis-name argument position per collective
_COLLECTIVES = {
    "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "all_gather": 1,
    "all_to_all": 1, "ppermute": 1, "pshuffle": 1, "psum_scatter": 1,
    "axis_index": 0, "axis_size": 0,
}

_FALLBACK_AXES = frozenset({"data", "model", "pod"})


def _axes_from_file(path: Path) -> FrozenSet[str]:
    axes = set()
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return frozenset()
    for node in ast.walk(tree):
        # every tuple-of-short-strings literal: mesh axes declarations like
        # ("pod", "data", "model") / dp_axes defaults / axes= kwargs
        if isinstance(node, ast.Tuple) and node.elts and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                and e.value.isidentifier() for e in node.elts):
            axes.update(e.value for e in node.elts)
        # string defaults of *_axis fields (fsdp_axis, tp_axis)
        if isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.target.id.endswith("_axis") \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, str):
            axes.add(node.value.value)
    return frozenset(axes)


@functools.lru_cache(maxsize=1)
def known_mesh_axes(repo_src: Optional[str] = None) -> FrozenSet[str]:
    """Axis names declared by the repo's mesh modules (AST-scraped)."""
    src = Path(repo_src) if repo_src else Path(__file__).resolve().parents[3]
    found = (_axes_from_file(src / "repro" / "parallel" / "sharding.py")
             | _axes_from_file(src / "repro" / "launch" / "mesh.py"))
    return found or _FALLBACK_AXES


class CollectiveAxisRule(Rule):
    """RPR004: literal collective axis names checked against the mesh axes
    declared in parallel/sharding.py + launch/mesh.py. Variables pass
    (resolved at run time); only misspelt literals are catchable early."""

    id = "RPR004"
    name = "collective-axis"

    def applies_to(self, path: str) -> bool:
        p = norm_path(path)
        return "repro/" in p or "benchmarks/" in p

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        axes = known_mesh_axes()
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = (node.func.attr if isinstance(node.func, ast.Attribute)
                    else node.func.id if isinstance(node.func, ast.Name)
                    else None)
            if name not in _COLLECTIVES:
                continue
            ids = _identifiers(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and not ids & {"lax", "jax"}:
                continue  # someone else's psum
            pos = _COLLECTIVES[name]
            arg = None
            if len(node.args) > pos:
                arg = node.args[pos]
            else:
                for kw in node.keywords:
                    if kw.arg in ("axis_name", "axis"):
                        arg = kw.value
            if arg is None:
                continue
            literals = []
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                literals = [arg]
            elif isinstance(arg, (ast.Tuple, ast.List)):
                literals = [e for e in arg.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)]
            for lit in literals:
                if lit.value not in axes:
                    yield self.finding(
                        ctx, lit,
                        f"{name}(..., {lit.value!r}): axis name not "
                        "declared by parallel/sharding.py or launch/mesh.py "
                        f"(known: {', '.join(sorted(axes))}) — typo'd axis "
                        "names only fail at run time inside shard_map")
