"""Rule catalogue for the JAX-hazard linter (see docs/static_analysis.md).

RPR001 traced-branch            Python control flow on traced values
RPR002 module-jnp-constant      jnp arrays built at import time
RPR003 traced-host-cast         .item()/int()/float()/bool() on tracers
RPR004 collective-axis          psum/collective axis names vs declared mesh
RPR005 bench-unsynced-timing    timed regions without block_until_ready
RPR006 registry-string-dispatch literal compares against registered names
RPR007 no-print-in-library      print() in library code (use logging)
RPR008 swallowed-exception      bare/no-op broad except hiding failures
"""
from __future__ import annotations

from typing import List

from repro.analysis.lint import Rule
from repro.analysis.rules.bench_timing import BenchTimingRule
from repro.analysis.rules.collectives import CollectiveAxisRule
from repro.analysis.rules.jax_hazards import (
    ModuleLevelJnpConstRule, TracedBranchRule, TracedHostCastRule)
from repro.analysis.rules.no_print import NoPrintRule
from repro.analysis.rules.registry_names import RegistryNameRule
from repro.analysis.rules.swallowed_exceptions import SwallowedExceptionRule


def all_rules() -> List[Rule]:
    return [
        TracedBranchRule(),
        ModuleLevelJnpConstRule(),
        TracedHostCastRule(),
        CollectiveAxisRule(),
        BenchTimingRule(),
        RegistryNameRule(),
        NoPrintRule(),
        SwallowedExceptionRule(),
    ]


__all__ = [
    "all_rules",
    "TracedBranchRule",
    "ModuleLevelJnpConstRule",
    "TracedHostCastRule",
    "CollectiveAxisRule",
    "BenchTimingRule",
    "RegistryNameRule",
    "NoPrintRule",
    "SwallowedExceptionRule",
]
