"""RPR007: no print() in library code.

Library modules (everything under ``repro/`` except ``repro/launch``) are
imported by tests, benchmarks, and serving hosts; a stray ``print`` writes
to whatever stdout happens to be attached — corrupting the CSV contract of
``benchmarks/common.emit_csv`` and bypassing log-level control. Use the
``logging`` module. CLI entry points (``repro/launch``, ``repro.analysis``'s
own ``__main__``) and tests are exempt.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint import FileContext, LintFinding, Rule, norm_path


class NoPrintRule(Rule):
    """RPR007: print() in library code — use logging instead."""

    id = "RPR007"
    name = "no-print-in-library"

    def applies_to(self, path: str) -> bool:
        p = norm_path(path)
        if "repro/analysis/__main__" in p:
            return False
        return ("repro/" in p and "repro/launch/" not in p
                and "/tests/" not in p)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "print":
                yield self.finding(
                    ctx, node,
                    "print() in library code writes to raw stdout — use a "
                    "module logger (logging.getLogger(__name__)) so hosts "
                    "control verbosity and benchmark CSV output stays clean")
