"""RPR006: registry names are looked up, not string-compared.

PR 5 replaced stringly-typed ``if metric == "router_logits"`` dispatch with
the registries in ``core/registry.py``; this rule keeps it that way. The set
of registered names is scraped (AST, no imports) from the ``@register_*``
decorators under ``src/repro/core``, so registering a new entry
automatically protects its name. A literal comparison against any of those
names in library code reintroduces a dispatch site that silently falls out
of sync when entries are added — route through ``METRICS.get`` /
``PLANNERS.get`` / plan metadata instead.
"""
from __future__ import annotations

import ast
import functools
from pathlib import Path
from typing import FrozenSet, Iterator, Optional

from repro.analysis.lint import FileContext, LintFinding, Rule, norm_path

_REGISTER_FNS = {"register_metric", "register_clustering", "register_merge",
                 "register_planner"}


@functools.lru_cache(maxsize=1)
def registered_names(repo_src: Optional[str] = None) -> FrozenSet[str]:
    """Names passed to @register_* decorators anywhere under repro/core."""
    src = Path(repo_src) if repo_src else Path(__file__).resolve().parents[3]
    names = set()
    core = src / "repro" / "core"
    if not core.is_dir():
        return frozenset()
    for file in sorted(core.glob("*.py")):
        try:
            tree = ast.parse(file.read_text())
        except (OSError, SyntaxError):
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                f = node.func
                fname = (f.attr if isinstance(f, ast.Attribute)
                         else f.id if isinstance(f, ast.Name) else None)
                if fname in _REGISTER_FNS and node.args \
                        and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    names.add(node.args[0].value)
    return frozenset(names)


class RegistryNameRule(Rule):
    """RPR006: equality/membership tests against registered metric /
    clustering / merge / planner names bypass core/registry.py dispatch."""

    id = "RPR006"
    name = "registry-string-dispatch"

    def applies_to(self, path: str) -> bool:
        p = norm_path(path)
        return ("repro/" in p and "repro/core/registry.py" not in p
                and "/tests/" not in p)

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        names = registered_names()
        if not names:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Compare):
                continue
            for op, comp in zip(node.ops, node.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)):
                    lits = ([comp] if isinstance(comp, ast.Constant)
                            else [])
                elif isinstance(op, (ast.In, ast.NotIn)) \
                        and isinstance(comp, (ast.Tuple, ast.List, ast.Set)):
                    lits = list(comp.elts)
                else:
                    continue
                for lit in lits:
                    if isinstance(lit, ast.Constant) \
                            and isinstance(lit.value, str) \
                            and lit.value in names:
                        yield self.finding(
                            ctx, lit,
                            f"string comparison against registered name "
                            f"{lit.value!r} bypasses core/registry.py — "
                            "dispatch through the registry (or plan "
                            "metadata) so new registrations keep working")
