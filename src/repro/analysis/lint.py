"""AST-based JAX-hazard linter: the engine.

Stdlib-only (the CI lint job runs it without jax installed). Rules live in
:mod:`repro.analysis.rules`; each is a subclass of :class:`Rule` with an id
(``RPR001``..), a path scope, and a ``check(tree, ctx)`` generator yielding
:class:`LintFinding`. The rule catalogue, rationale, and suppression syntax
are documented in docs/static_analysis.md.

Suppression: a trailing ``# noqa: RPR001`` (comma-separated ids) on the
flagged line, or a bare ``# noqa`` which suppresses every rule on that line
— same syntax ruff uses, so one comment can silence both linters.

Entry points: :func:`lint_paths` (CLI: ``python -m repro.analysis lint
src/ benchmarks/``) and :func:`lint_source` (fixture tests).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path, PurePosixPath
from typing import Dict, Iterable, Iterator, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass
class FileContext:
    """Per-file state handed to every rule."""

    path: str             # posix-style, repo-relative where possible
    source: str
    lines: List[str] = dataclasses.field(default_factory=list)
    parents: Dict[ast.AST, ast.AST] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.lines:
            self.lines = self.source.splitlines()

    def build_parents(self, tree: ast.AST):
        if not self.parents:
            for node in ast.walk(tree):
                for child in ast.iter_child_nodes(node):
                    self.parents[child] = node
        return self.parents


class Rule:
    """Base class: subclasses set ``id``, ``name``, and implement check()."""

    id: str = "RPR000"
    name: str = "base"

    def applies_to(self, path: str) -> bool:
        return path.endswith(".py")

    def check(self, tree: ast.AST, ctx: FileContext
              ) -> Iterator[LintFinding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST, message: str
                ) -> LintFinding:
        return LintFinding(ctx.path, getattr(node, "lineno", 0),
                           getattr(node, "col_offset", 0), self.id, message)


# ---------------------------------------------------------------------------
# path scoping helpers shared by the rules
# ---------------------------------------------------------------------------


def norm_path(path) -> str:
    return PurePosixPath(str(path).replace("\\", "/")).as_posix()


def in_library(path: str) -> bool:
    """src/repro minus the CLI entrypoints in launch/."""
    p = norm_path(path)
    return "repro/" in p and "repro/launch/" not in p and "/tests/" not in p


def in_benchmarks(path: str) -> bool:
    return "benchmarks/" in norm_path(path)


_NOQA = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.IGNORECASE)


def _suppressed(finding: LintFinding, lines: Sequence[str]) -> bool:
    if not (1 <= finding.line <= len(lines)):
        return False
    m = _NOQA.search(lines[finding.line - 1])
    if not m:
        return False
    codes = m.group("codes")
    if codes is None:
        return True                      # bare `# noqa`
    return finding.rule in {c.strip().upper() for c in codes.split(",")}


def default_rules() -> List[Rule]:
    from repro.analysis import rules as rules_pkg

    return rules_pkg.all_rules()


def lint_source(source: str, path: str = "src/repro/_memory_.py",
                rules: Optional[Sequence[Rule]] = None) -> List[LintFinding]:
    """Lint one source string as though it lived at ``path`` (the path
    drives rule scoping — pass a benchmarks/ path to hit bench rules)."""
    rules = list(rules) if rules is not None else default_rules()
    path = norm_path(path)
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, e.offset or 0, "RPR000",
                            f"syntax error: {e.msg}")]
    ctx = FileContext(path=path, source=source)
    out: List[LintFinding] = []
    for rule in rules:
        if rule.applies_to(path):
            out.extend(rule.check(tree, ctx))
    return sorted((f for f in out if not _suppressed(f, ctx.lines)),
                  key=lambda f: (f.path, f.line, f.col, f.rule))


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[Rule]] = None) -> List[LintFinding]:
    rules = list(rules) if rules is not None else default_rules()
    out: List[LintFinding] = []
    for file in iter_python_files(paths):
        out.extend(lint_source(file.read_text(), norm_path(file), rules))
    return out
