"""Static analysis for the repro codebase: kernel contract verification,
JAX-hazard linting, and runtime shape/dtype contracts.

Three layers (docs/static_analysis.md):

  * ``kernel_verify`` — host-side exhaustive verification of every
    ``pallas_call`` launch site in ``repro.kernels``: index maps are
    evaluated over the full grid and proved in-bounds / clamp-coherent /
    covering, out_specs proved to tile the output exactly once.
  * ``lint`` + ``rules`` — an AST linter for repo-specific JAX hazards ruff
    cannot express (tracer-dependent Python control flow, module-level jnp
    constants, collective axis-name typos, un-synchronised timed regions,
    stringly registry dispatch, prints in library code).
  * ``contracts`` — the ``@checked`` shape/dtype-spec decorator on the hot
    public interfaces, enabled under tests/CI and zero-cost when off.

CLI: ``python -m repro.analysis kernels`` / ``python -m repro.analysis lint
PATH...``. The lint layer is stdlib-only so the CI lint job runs it without
installing jax; importing :mod:`repro.analysis` itself stays light — the
jax-dependent verifier loads only on attribute access.
"""
from __future__ import annotations

__all__ = ["contracts", "kernel_verify", "lint", "rules"]


def __getattr__(name):
    # lazy: `import repro.analysis.lint` must not pull jax in (CI lint job)
    if name in __all__:
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(name)
