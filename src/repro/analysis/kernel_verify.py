"""Host-side contract verification for every Pallas kernel launch site.

The verifier intercepts ``pl.pallas_call`` (:func:`capture_launches`) so each
kernel wrapper in ``repro.kernels`` is driven with real, tiny operands and its
REAL grid / BlockSpecs / scalar-prefetch operands are captured — nothing is
re-declared by hand, so the checked spec cannot drift from the shipped one.
Every BlockSpec index map is then evaluated exhaustively over the full grid
on the host, and the following invariants are proved per launch
(:func:`verify_capture`):

  * **in-bounds** — every DMA'd block of every operand lies inside the
    (padded) array; scalar-prefetch indexing out of SMEM bounds raises.
  * **divisibility** — block shapes divide their operand dims exactly (the
    repo convention: wrappers pick gcd tile sizes, never relying on Pallas
    edge padding).
  * **clamp coherence** — for operands with DMA-eliding clamped index maps,
    a tile the kernel's live gate RUNS must fetch its own (nominal) block:
    ``live(cell)  ⟹  index_map(cell) == nominal(cell)``. A live-gated cell
    whose DMA was clamped re-reads an already-resident block and
    double-counts it — exactly the PR 4 sliding-window lower-skip
    off-by-one. The gate predicates are the module-level ``live_tile*``
    functions the kernel bodies themselves run (kernels/flash_decode.py,
    kernels/flash_attention.py), and the clamps live in the index maps, so
    the two independent formulations are cross-checked, not assumed.
  * **coverage** — every tile that semantically holds unmasked data (derived
    from the actual kv_pos/page-table contents of the battery case, NOT from
    the gate formula) is gated live: the skip logic can never drop real
    rows.
  * **output exactly once** — the distinct out-spec block indices tile the
    output array exactly; each output block is written by exactly one
    parallel grid point (revisited across all "arbitrary" accumulation
    steps, per the repo's write-on-last-step convention).
  * **scalar dtypes** — scalar-prefetch operands are integer-typed (SMEM).
  * **VMEM budget** — per-step block + scratch bytes stay inside the
    ~16 MB/core budget.

:func:`build_cases` is the battery: representative shape/position configs for
all five launch sites (flash_attention, flash_decode, flash_decode_paged,
moe_gemm, fused_ffn), including ring wrap-around, sliding windows (decode AND
the fused windowed/softcap prefill), empty slots, the (pos-window) % page ==
page-1 boundary from the PR 4 bug, gcd tiling, zero-sized expert groups, and
the per-shard shapes the shard_map wrappers in repro.kernels.partition launch
under an expert-parallel serving mesh. ``python -m repro.analysis kernels``
runs it; tests/test_analysis_kernels.py additionally proves the PR 4
off-by-one is *detected* when reintroduced in a toy kernel.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

VMEM_BUDGET_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at one launch site."""

    site: str
    check: str        # in_bounds | divisibility | clamp | coverage | output
                      # | scalars | semantics | vmem | capture
    message: str
    cell: Optional[Tuple[int, ...]] = None

    def __str__(self):
        at = f" at grid cell {self.cell}" if self.cell is not None else ""
        return f"{self.site}: [{self.check}]{at} {self.message}"


@dataclasses.dataclass
class SpecView:
    """One captured BlockSpec next to its operand's shape/dtype."""

    block_shape: Tuple[int, ...]
    index_map: Callable
    shape: Tuple[int, ...]
    dtype: np.dtype


@dataclasses.dataclass
class Capture:
    """Everything recorded from one intercepted ``pl.pallas_call``."""

    kernel_name: str
    grid: Tuple[int, ...]
    in_specs: List[SpecView]
    out_specs: List[SpecView]
    num_scalar_prefetch: int
    scalars: Tuple[np.ndarray, ...]
    dimension_semantics: Optional[Tuple[str, ...]]
    scratch: List[Tuple[Tuple[int, ...], np.dtype]]
    operands: Tuple[np.ndarray, ...] = ()

    def cells(self):
        return itertools.product(*(range(n) for n in self.grid))

    def eval_map(self, spec: SpecView, cell) -> Tuple[int, ...]:
        idx = spec.index_map(*cell, *self.scalars)
        if not isinstance(idx, tuple):
            idx = (idx,)
        return tuple(int(c) for c in idx)


@contextlib.contextmanager
def capture_launches(captures: List[Capture]):
    """Patch ``pallas_call`` so wrapped kernels record their launch spec
    instead of executing; the fake call returns zeros of ``out_shape``."""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl_mod

    real = pl_mod.pallas_call

    def fake_pallas_call(kernel, *, grid_spec=None, grid=None, in_specs=None,
                         out_specs=None, out_shape=None, scratch_shapes=(),
                         compiler_params=None, interpret=False, **kw):
        if grid_spec is not None:
            grid_, in_specs_, out_specs_ = (grid_spec.grid, grid_spec.in_specs,
                                            grid_spec.out_specs)
            nsp = getattr(grid_spec, "num_scalar_prefetch", 0)
            scratch = getattr(grid_spec, "scratch_shapes", ())
        else:
            grid_, in_specs_, out_specs_ = grid, in_specs, out_specs
            nsp, scratch = 0, scratch_shapes
        if not isinstance(out_specs_, (list, tuple)):
            out_specs_ = [out_specs_]
        out_shapes = (list(out_shape) if isinstance(out_shape, (list, tuple))
                      else [out_shape])

        def runner(*operands):
            scalars = tuple(np.asarray(s) for s in operands[:nsp])
            arrays = tuple(np.asarray(a) for a in operands[nsp:])
            cap = Capture(
                kernel_name=getattr(kernel, "func", kernel).__name__
                if hasattr(kernel, "func") else kernel.__name__,
                grid=tuple(int(g) for g in grid_),
                in_specs=[SpecView(tuple(s.block_shape), s.index_map,
                                   a.shape, a.dtype)
                          for s, a in zip(in_specs_, arrays)],
                out_specs=[SpecView(tuple(s.block_shape), s.index_map,
                                    tuple(o.shape), np.dtype(o.dtype))
                           for s, o in zip(out_specs_, out_shapes)],
                num_scalar_prefetch=nsp,
                scalars=scalars,
                dimension_semantics=getattr(compiler_params,
                                            "dimension_semantics", None),
                scratch=[(tuple(getattr(s, "shape", ())),
                          np.dtype(getattr(s, "dtype", np.float32)))
                         for s in scratch],
                operands=arrays,
            )
            captures.append(cap)
            outs = [jnp.zeros(o.shape, o.dtype) for o in out_shapes]
            return outs[0] if not isinstance(out_shape, (list, tuple)) else outs

        return runner

    pl_mod.pallas_call = fake_pallas_call
    try:
        yield captures
    finally:
        pl_mod.pallas_call = real


@dataclasses.dataclass
class KernelCase:
    """One battery entry: a launch trigger plus its semantic contract.

    ``live`` mirrors the kernel's @pl.when compute gate (imported from the
    kernel module — the same function the kernel body traces). ``nominal``
    gives, per in-spec position with a DMA-eliding clamped index map, the
    UNCLAMPED block index a live tile must fetch. ``required_live`` derives,
    from the captured operand contents alone, the grid cells that must be
    gated live for correctness.
    """

    name: str
    run: Callable[[], None]
    live: Optional[Callable[[Capture, Tuple[int, ...]], bool]] = None
    nominal: Dict[int, Callable] = dataclasses.field(default_factory=dict)
    required_live: Optional[Callable[[Capture], Iterable[Tuple[int, ...]]]] \
        = None


def _check_specs(site, specs, kind, grid, cap, findings):
    """Shared in-bounds + divisibility sweep; returns per-spec cell->idx."""
    evaluated = []
    for si, spec in enumerate(specs):
        where = f"{kind}_spec[{si}]"
        if len(spec.block_shape) != len(spec.shape):
            findings.append(Finding(site, "divisibility",
                                    f"{where}: block rank "
                                    f"{len(spec.block_shape)} vs operand rank "
                                    f"{len(spec.shape)}"))
            evaluated.append({})
            continue
        for d, (b, s) in enumerate(zip(spec.block_shape, spec.shape)):
            if b is None:
                continue
            if b < 1 or b > s:
                findings.append(Finding(
                    site, "divisibility",
                    f"{where}: block dim {d} = {b} outside [1, {s}]"))
            elif s % b != 0:
                findings.append(Finding(
                    site, "divisibility",
                    f"{where}: block dim {d} = {b} does not divide "
                    f"operand dim {s} (Pallas would pad; repo convention "
                    "is exact gcd tiling)"))
        cell_idx = {}
        for cell in cap.cells():
            try:
                idx = cap.eval_map(spec, cell)
            except IndexError as e:
                findings.append(Finding(
                    site, "scalars",
                    f"{where}: index map raised on SMEM scalar lookup: {e}",
                    cell))
                continue
            cell_idx[cell] = idx
            if len(idx) != len(spec.block_shape):
                findings.append(Finding(
                    site, "in_bounds",
                    f"{where}: index map returned rank {len(idx)} vs block "
                    f"rank {len(spec.block_shape)}", cell))
                continue
            for d, (i, b, s) in enumerate(
                    zip(idx, spec.block_shape, spec.shape)):
                if b is None:
                    b = 1
                if i < 0 or (i + 1) * b > s:
                    findings.append(Finding(
                        site, "in_bounds",
                        f"{where}: block index {idx} puts dim {d} rows "
                        f"[{i * b}, {(i + 1) * b}) outside operand dim {s}",
                        cell))
        evaluated.append(cell_idx)
    return evaluated


def _parallel_arb_dims(cap: Capture):
    sem = cap.dimension_semantics
    if sem is None:
        return tuple(range(len(cap.grid))), ()
    par = tuple(i for i, s in enumerate(sem) if s == "parallel")
    arb = tuple(i for i, s in enumerate(sem) if s != "parallel")
    return par, arb


def verify_capture(case: KernelCase, cap: Capture) -> List[Finding]:
    findings: List[Finding] = []
    site = f"{case.name}/{cap.kernel_name}"

    # ---- dimension semantics sanity
    sem = cap.dimension_semantics
    if sem is not None:
        if len(sem) != len(cap.grid):
            findings.append(Finding(
                site, "semantics",
                f"dimension_semantics {sem} rank vs grid {cap.grid}"))
        if any(a == "parallel" and i > 0 and sem[i - 1] != "parallel"
               for i, a in enumerate(sem)):
            findings.append(Finding(
                site, "semantics",
                f"'parallel' after 'arbitrary' in {sem}: TPU grids need "
                "accumulation dims innermost"))

    # ---- scalar prefetch operands live in SMEM: integer dtype
    for i, s in enumerate(cap.scalars):
        if not np.issubdtype(s.dtype, np.integer):
            findings.append(Finding(
                site, "scalars",
                f"scalar-prefetch operand {i} has dtype {s.dtype}, "
                "expected an integer SMEM type"))

    # ---- in-bounds + divisibility on every spec over the full grid
    in_eval = _check_specs(site, cap.in_specs, "in", cap.grid, cap, findings)
    out_eval = _check_specs(site, cap.out_specs, "out", cap.grid, cap,
                            findings)

    # ---- live-gate model over the grid
    live_cells = set()
    if case.live is not None:
        for cell in cap.cells():
            if bool(case.live(cap, cell)):
                live_cells.add(cell)
    else:
        live_cells = set(cap.cells())

    # ---- clamp coherence: a live tile must fetch its own (nominal) block
    for si, nominal in case.nominal.items():
        cell_idx = in_eval[si]
        for cell in cap.cells():
            if cell not in cell_idx or cell not in live_cells:
                continue
            want = tuple(int(c) for c in nominal(cap, cell))
            got = cell_idx[cell]
            if got != want:
                findings.append(Finding(
                    site, "clamp",
                    f"in_spec[{si}]: cell is gated LIVE but its DMA is "
                    f"clamped to block {got} instead of nominal {want} — "
                    "the kernel would re-read an already-resident block "
                    "and double-count it (PR 4 bug class)", cell))

    # ---- coverage: semantically required tiles must be gated live
    if case.required_live is not None:
        for cell in case.required_live(cap):
            cell = tuple(int(c) for c in cell)
            if cell not in live_cells:
                findings.append(Finding(
                    site, "coverage",
                    "tile holds unmasked rows (per the captured kv/pos "
                    "contents) but the live gate skips it", cell))

    # ---- output blocks: tile the array exactly once
    par_dims, arb_dims = _parallel_arb_dims(cap)
    n_arb = int(np.prod([cap.grid[d] for d in arb_dims])) if arb_dims else 1
    for si, spec in enumerate(cap.out_specs):
        cell_idx = out_eval[si]
        if len(cell_idx) != int(np.prod(cap.grid)):
            continue  # map itself failed; already reported
        groups: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
        for cell, idx in cell_idx.items():
            groups.setdefault(idx, []).append(cell)
        n_blocks = 1
        ok_shape = True
        for b, s in zip(spec.block_shape, spec.shape):
            b = 1 if b is None else b
            if s % b:
                ok_shape = False
            n_blocks *= s // max(b, 1)
        if ok_shape and len(groups) != n_blocks:
            findings.append(Finding(
                site, "output",
                f"out_spec[{si}]: grid writes {len(groups)} distinct blocks "
                f"but the output has {n_blocks} — "
                + ("some blocks are never written"
                   if len(groups) < n_blocks else "blocks written twice")))
        for idx, cells in groups.items():
            pcoords = {tuple(c[d] for d in par_dims) for c in cells}
            if len(pcoords) > 1:
                findings.append(Finding(
                    site, "output",
                    f"out_spec[{si}]: block {idx} is written by "
                    f"{len(pcoords)} distinct parallel grid points "
                    f"{sorted(pcoords)[:4]} — racing writes"))
            if len(cells) != n_arb:
                findings.append(Finding(
                    site, "output",
                    f"out_spec[{si}]: block {idx} is visited {len(cells)} "
                    f"times, expected the full accumulation depth {n_arb}"))

    # ---- VMEM budget per grid step
    bytes_ = 0
    for spec in list(cap.in_specs) + list(cap.out_specs):
        blk = [1 if b is None else b for b in spec.block_shape]
        bytes_ += int(np.prod(blk)) * spec.dtype.itemsize
    for shape, dtype in cap.scratch:
        bytes_ += int(np.prod(shape)) * dtype.itemsize
    if bytes_ > VMEM_BUDGET_BYTES:
        findings.append(Finding(
            site, "vmem",
            f"per-step VMEM working set {bytes_ / 1e6:.1f} MB exceeds the "
            f"{VMEM_BUDGET_BYTES / 1e6:.0f} MB/core budget"))

    return findings


def verify_case(case: KernelCase) -> List[Finding]:
    captures: List[Capture] = []
    with capture_launches(captures):
        case.run()
    if not captures:
        return [Finding(case.name, "capture",
                        "case triggered no pallas_call launch")]
    findings: List[Finding] = []
    for cap in captures:
        findings.extend(verify_capture(case, cap))
    return findings


# ---------------------------------------------------------------------------
# The battery: every launch site in repro.kernels
# ---------------------------------------------------------------------------


def _ring_kv_pos(w: int, pos: Sequence[int]) -> np.ndarray:
    """The serving engine's ring fill: row r of slot b holds the newest
    absolute position p <= pos[b] with p % w == r, or -1 if none exists."""
    out = np.full((len(pos), w), -1, np.int32)
    for b, p in enumerate(pos):
        if p < 0:
            continue
        for r in range(w):
            q = p - ((p - r) % w)
            if q >= 0:
                out[b, r] = q
    return out


def _decode_required(cap: Capture, *, window: int):
    """Tiles holding any row that passes the decode mask, from the captured
    kv_pos contents — independent of the kernel's skip formula."""
    (pos,) = cap.scalars
    kvp = cap.operands[3]                       # (B, W) ring kv_pos
    b_n, k_n, _ = cap.grid
    tk = cap.in_specs[1].block_shape[1]
    req = []
    for b in range(b_n):
        ok = (kvp[b] >= 0) & (kvp[b] <= pos[b])
        if window:
            ok &= (pos[b] - kvp[b]) < window
        for t in np.unique(np.nonzero(ok)[0] // tk):
            req.extend((b, kh, int(t)) for kh in range(k_n))
    return req


def _paged_required(cap: Capture, *, window: int):
    (pos, _pt) = cap.scalars
    b_n, k_n, _ = cap.grid
    page = cap.in_specs[1].block_shape[1]
    req = []
    for b in range(b_n):
        if pos[b] < 0:
            continue
        lo = max(pos[b] - window + 1, 0) if window else 0
        tiles = {p // page for p in range(lo, pos[b] + 1)}
        req.extend((b, kh, int(t)) for kh in range(k_n) for t in tiles)
    return req


def _flash_decode_case(name, *, w, pos, window=0, k_heads=2, g=2, hd=8,
                       logit_cap=0.0):
    import jax.numpy as jnp

    from repro.kernels import flash_decode as fd

    b_n = len(pos)
    h = k_heads * g
    rng = np.random.RandomState(0)

    def run():
        q = jnp.asarray(rng.randn(b_n, h, hd), jnp.float32)
        k = jnp.asarray(rng.randn(b_n, w, k_heads, hd), jnp.float32)
        v = jnp.asarray(rng.randn(b_n, w, k_heads, hd), jnp.float32)
        kv_pos = jnp.asarray(_ring_kv_pos(w, pos))
        fd.flash_decode(q, k, v, kv_pos, jnp.asarray(pos, jnp.int32),
                        window=window, logit_cap=logit_cap)

    tk = w if w <= fd.TK else math.gcd(w, fd.TK)

    def live(cap, cell):
        b, _kh, ki = cell
        return bool(fd.live_tile(ki, int(cap.scalars[0][b]), tk=tk, w=w))

    def nominal_kv(cap, cell):
        b, kh, ki = cell
        return (b, ki, kh)

    def nominal_kvp(cap, cell):
        b, _kh, ki = cell
        return (b, ki)

    import functools

    return KernelCase(
        name=name, run=run, live=live,
        nominal={1: nominal_kv, 2: nominal_kv, 3: nominal_kvp},
        required_live=functools.partial(_decode_required, window=window),
    )


def _paged_pools(pos, page, table_len, k_heads, hd, shared=0):
    """Toy allocator state mirroring PageAllocator: slot b owns consecutive
    physical pages covering logical rows [0, pos[b]]; page 0 is the null
    page (kv_pos all -1). With ``shared`` > 0, every live slot ALIASES the
    same ``shared`` leading physical pages — the prefix-cache splice state
    (PageAllocator.splice_prefix), where one refcounted set of pages backs
    logical rows [0, shared*page) of several page tables at once. Live
    slots must then satisfy ``pos >= shared*page``."""
    own = [max(0, -(-(p + 1) // page) - shared) for p in pos if p >= 0]
    n = 1 + shared + sum(own) + 1  # null + shared prefix + owned + spare
    kv_pos = np.full((n, page), -1, np.int32)
    table = np.zeros((len(pos), table_len), np.int32)
    for j in range(shared):
        kv_pos[1 + j] = np.arange(j * page, (j + 1) * page)
    nxt = 1 + shared
    for b, p in enumerate(pos):
        if p < 0:
            continue
        assert p >= shared * page, (
            f"slot {b}: pos {p} does not cover the {shared} shared page(s)")
        table[b, :shared] = 1 + np.arange(shared)
        for j in range(shared, -(-(p + 1) // page)):
            table[b, j] = nxt
            rows = np.arange(j * page, min((j + 1) * page, p + 1))
            kv_pos[nxt, : len(rows)] = rows
            nxt += 1
    return n, kv_pos, table


def _flash_decode_paged_case(name, *, page, table_len, pos, window=0,
                             k_heads=2, g=2, hd=8, shared=0):
    import functools

    import jax.numpy as jnp

    from repro.kernels import flash_decode as fd

    b_n = len(pos)
    h = k_heads * g
    n, kv_pos, table = _paged_pools(pos, page, table_len, k_heads, hd,
                                    shared=shared)
    rng = np.random.RandomState(0)

    def run():
        q = jnp.asarray(rng.randn(b_n, h, hd), jnp.float32)
        kp = jnp.asarray(rng.randn(n, page, k_heads, hd), jnp.float32)
        vp = jnp.asarray(rng.randn(n, page, k_heads, hd), jnp.float32)
        fd.flash_decode_paged(q, kp, vp, jnp.asarray(kv_pos),
                              jnp.asarray(table),
                              jnp.asarray(pos, jnp.int32), window=window)

    def live(cap, cell):
        b, _kh, ki = cell
        return bool(fd.live_tile_paged(ki, int(cap.scalars[0][b]),
                                       page=page, window=window))

    def nominal_kv(cap, cell):
        b, kh, ki = cell
        return (int(cap.scalars[1][b, ki]), 0, kh)

    def nominal_kvp(cap, cell):
        b, _kh, ki = cell
        return (int(cap.scalars[1][b, ki]), 0)

    return KernelCase(
        name=name, run=run, live=live,
        nominal={1: nominal_kv, 2: nominal_kv, 3: nominal_kvp},
        required_live=functools.partial(_paged_required, window=window),
    )


def _flash_attention_case(name, *, s, causal, b_n=2, k_heads=2, g=2, hd=8,
                          window=0, logit_cap=0.0):
    import jax.numpy as jnp

    from repro.kernels import flash_attention as fa

    h = k_heads * g
    tq = math.gcd(s, fa.TQ)
    tk = math.gcd(s, fa.TK)
    rng = np.random.RandomState(0)

    def run():
        q = jnp.asarray(rng.randn(b_n, s, h, hd), jnp.float32)
        k = jnp.asarray(rng.randn(b_n, s, k_heads, hd), jnp.float32)
        v = jnp.asarray(rng.randn(b_n, s, k_heads, hd), jnp.float32)
        fa.flash_attention(q, k, v, causal=causal, window=window,
                           logit_cap=logit_cap)

    def live(cap, cell):
        _bh, qi, ki = cell
        return bool(fa.live_tile(qi, ki, tq=tq, tk=tk, causal=causal,
                                 window=window))

    def required(cap):
        # attention semantics: the rows of q tile qi need every key position
        # some row attends to — [max(row - window + 1, 0), row] per row,
        # unioned over the tile, intersected with causality
        bh_n, q_n, _ = cap.grid
        req = []
        for qi in range(q_n):
            hi = qi * tq + tq - 1 if causal else s - 1
            lo = max(qi * tq - (window - 1), 0) if window else 0
            req.extend((bh, qi, ki) for bh in range(bh_n)
                       for ki in range(lo // tk, hi // tk + 1))
        return req

    def nominal_kv(cap, cell):
        # live k steps must fetch their own tile: clip(j, lo, hi) == j.
        # folded KV batch row for query-head cell bh is bh // (H/K)
        bh, _qi, ki = cell
        return (bh // g, ki, 0)

    return KernelCase(name=name, run=run, live=live,
                      nominal={1: nominal_kv, 2: nominal_kv},
                      required_live=required)


def _moe_gemm_case(name, *, e, d, f, group_sizes):
    import jax.numpy as jnp

    from repro.kernels import moe_gemm as mg

    rng = np.random.RandomState(0)
    gs = jnp.asarray(group_sizes, jnp.int32)
    num_tokens = int(sum(group_sizes))

    def run():
        _dest, tile_expert, n_pad = mg.padded_layout(gs, num_tokens)
        x_pad = jnp.asarray(rng.randn(int(n_pad), d), jnp.float32)
        w = jnp.asarray(rng.randn(e, d, f), jnp.float32)
        mg.grouped_matmul_padded(x_pad, w, tile_expert)

    return KernelCase(name=name, run=run)


def _fused_ffn_case(name, *, m, d, f, act):
    import jax.numpy as jnp

    from repro.kernels import fused_ffn as ff

    rng = np.random.RandomState(0)

    def run():
        x = jnp.asarray(rng.randn(m, d), jnp.float32)
        wg = jnp.asarray(rng.randn(d, f), jnp.float32)
        wu = jnp.asarray(rng.randn(d, f), jnp.float32)
        wd = jnp.asarray(rng.randn(f, d), jnp.float32)
        ff.fused_ffn(x, wg, wu, wd, act)

    return KernelCase(name=name, run=run)


def build_cases() -> List[KernelCase]:
    """The five launch sites × representative shape/position configs."""
    return [
        # flash_decode: 2-tile ring, empty slot / fresh / wrapped
        _flash_decode_case("flash_decode/w256", w=256, pos=[-1, 0, 300]),
        # single odd tile (w <= TK path), boundary positions
        _flash_decode_case("flash_decode/w40", w=40, pos=[5, 39, 40, -1]),
        # gcd tiling, 3 tiles, mid-fill
        _flash_decode_case("flash_decode/w384", w=384, pos=[129, 255, 383]),
        # sliding window + softcap on the ring layout
        _flash_decode_case("flash_decode/w256_win64", w=256, window=64,
                           pos=[10, 100, 300], logit_cap=30.0),
        # paged: growth across pages, empty slot, page-boundary positions
        _flash_decode_paged_case("flash_decode_paged/p8", page=8, table_len=4,
                                 pos=[-1, 0, 17, 31]),
        # paged sliding window incl. the PR 4 trap: (pos-window) % page ==
        # page-1 (pos=19, window=12, page=8 -> 19-12=7)
        _flash_decode_paged_case("flash_decode_paged/p8_win12", page=8,
                                 table_len=4, pos=[19, 20, 27, 31],
                                 window=12),
        # window smaller than a page / window spanning all pages
        _flash_decode_paged_case("flash_decode_paged/p16_win5", page=16,
                                 table_len=2, pos=[3, 18, 31], window=5),
        # SHARED page table (prefix-cache splice): three decode slots alias
        # the same two physical prefix pages at different total lengths,
        # plus a dead slot — the page-table indirection must read aliased
        # rows identically for every consumer (the reason the kernel needs
        # NO change for cross-request prefix caching)
        _flash_decode_paged_case("flash_decode_paged/p8_shared2", page=8,
                                 table_len=4, pos=[19, 23, 31, -1],
                                 shared=2),
        # aliased prefix under a sliding window that ends INSIDE the
        # shared pages for the shortest consumer (pos=17, window=12 ->
        # first live row 6 lands in shared page 0)
        _flash_decode_paged_case("flash_decode_paged/p8_shared2_win12",
                                 page=8, table_len=4, pos=[17, 24, 31],
                                 window=12, shared=2),
        # flash_attention: 128-tiles and odd gcd tiles, causal + full
        _flash_attention_case("flash_attention/s256_causal", s=256,
                              causal=True),
        _flash_attention_case("flash_attention/s256_full", s=256,
                              causal=False),
        _flash_attention_case("flash_attention/s40_causal", s=40,
                              causal=True),
        # windowed prefill: the band straddles KV-tile seams (s=256 -> two
        # 128 tiles, window=40 crosses at rows 128..167) and the clamped
        # lo/hi index map + band live gate are cross-checked
        _flash_attention_case("flash_attention/s256_win40", s=256,
                              causal=True, window=40),
        # window below one gcd tile + softcap fused (gemma2-style locals)
        _flash_attention_case("flash_attention/s64_win16_cap", s=64,
                              causal=True, window=16, logit_cap=50.0),
        # per-shard launches under the serving mesh ('heads' mode,
        # K % tp == 0): shard_map partitions operands BEFORE pallas_call, so
        # each device launches the identical kernel at K/tp kv heads and
        # H/tp q heads — verified here at exactly those per-shard shapes
        # (shard_map traces with abstract operands, so the capture hook
        # cannot observe contents through it; 'gather' mode launches at the
        # full shapes the existing cases already cover)
        _flash_decode_case("flash_decode/ep_heads_shard", w=256,
                           pos=[-1, 0, 300], k_heads=1, g=2),
        _flash_decode_paged_case("flash_decode_paged/ep_heads_shard", page=8,
                                 table_len=4, pos=[19, 27, 31], window=12,
                                 k_heads=1, g=2),
        _flash_attention_case("flash_attention/ep_heads_shard", s=128,
                              causal=True, k_heads=1, g=2),
        # moe_gemm: ragged groups incl. a zero-sized expert
        _moe_gemm_case("moe_gemm/e3", e=3, d=16, f=32,
                       group_sizes=[5, 0, 130]),
        _moe_gemm_case("moe_gemm/e4_even", e=4, d=16, f=256,
                       group_sizes=[128, 128, 128, 128]),
        # fused_ffn: both activations, gcd tiles
        _fused_ffn_case("fused_ffn/silu", m=8, d=16, f=64, act="silu"),
        _fused_ffn_case("fused_ffn/gelu", m=24, d=16, f=96, act="gelu"),
    ]


def verify_all(cases: Optional[List[KernelCase]] = None
               ) -> Dict[str, List[Finding]]:
    """Run the battery; returns {case name: findings} (empty list = pass)."""
    return {c.name: verify_case(c) for c in (cases or build_cases())}
