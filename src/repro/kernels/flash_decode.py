"""Pallas TPU kernel: length-aware split-KV flash decode for GQA serving.

One decode step attends each request's single query token against its
ring-buffered KV cache. The grid is (B, K, W/TK) — batch slot x KV head x
KV tile — with the KV dimension innermost ("arbitrary" semantics: it
accumulates an online softmax in VMEM scratch, exactly like the prefill
flash kernel). GQA is handled natively: q is laid out (B*K, H/K, hd) so
every grid cell contracts its whole head group against ONE un-expanded
(TK, hd) K/V tile — the ``_expand_kv`` materialization (H/K x redundant
K/V traffic per decode step) never happens.

Ring-buffer semantics are fused in-kernel: each cached slot carries its
absolute position ``kv_pos`` (-1 = unfilled), and the mask
``kv_pos >= 0 & kv_pos <= pos [& pos - kv_pos < window]`` reproduces the
jnp decode mask bit-for-bit, including sliding-window local layers and
post-wrap caches. Logit soft-capping is applied before masking, matching
``repro.models.attention._attend``.

Length-aware tile skipping: the per-slot query position ``pos`` is
scalar-prefetched into SMEM. The engine's ring buffer fills slots
``0..min(pos+1, W)-1`` densely (sequential writes at ``pos % W``;
admission splices reset ``kv_pos`` wholesale), so every tile at or beyond
``min(pos+1, W)`` holds only unfilled slots. Those tiles are skipped two
ways: ``@pl.when`` elides the compute, and the K/V/kv_pos index maps clamp
the tile index to the last valid tile so the pipelined DMA re-targets an
already-resident block instead of streaming dead cache lines. Short
requests in a long-``max_len`` engine therefore pay O(len), not O(max_len).

VMEM per step: G*hd (q) + 2*TK*hd (k,v) + G*TK logits + G*hd f32 acc —
~0.13 MB at G=8, TK=128, hd=128, far inside the ~16 MB/core budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.contracts import checked
from repro.kernels._compat import CompilerParams as _CompilerParams

TK = 128
NEG = -2.0e38


# ---------------------------------------------------------------------------
# Live-tile predicates (the @pl.when compute gates)
# ---------------------------------------------------------------------------
# Each kernel's tile-skip gate is defined ONCE here, at module level, and is
# used both by the kernel body (on traced scalars) and by the host-side
# contract verifier (repro.analysis.kernel_verify, on concrete ints). The
# verifier proves the gate agrees with the DMA-eliding index-map clamps
# (`_last_tile` / the paged live range below) over the full grid — the two
# formulations are kept independent on purpose, because their silent
# disagreement IS the bug class being guarded against: a dead tile running
# on a clamped DMA double-counts an already-resident block (the PR 4
# sliding-window lower-skip off-by-one).


def live_tile(ki, pos_b, *, tk, w):
    """True iff contiguous-ring KV tile ``ki`` holds any filled row for a
    slot whose query position is ``pos_b`` (-1 = empty slot)."""
    n_valid = jnp.minimum(pos_b + 1, w)
    return ki * tk < n_valid


def live_tile_paged(ki, pos_b, *, page, window):
    """True iff page-tile ``ki`` holds any unmasked row for a slot at
    ``pos_b``. Paged caches never wrap, so a sliding window bounds the live
    range from below too: a tile is live iff its last row ``(ki+1)*page - 1``
    reaches ``pos_b - window + 1`` (see _paged_kernel)."""
    run = ki * page < pos_b + 1
    if window:
        run &= (ki + 1) * page > pos_b - window + 1
    return run


def _online_step(q_ref, k_ref, v_ref, kvp_ref, m_scr, l_scr, acc_scr,
                 pos_b, *, scale, window, logit_cap):
    """One KV tile of the online softmax, shared by the contiguous and paged
    kernels: softcap, filled/causal/window masking, rescale, accumulate."""
    q = q_ref[0]          # (G, hd)
    k = k_ref[0]          # (TK, hd)
    v = v_ref[0]
    kvp = kvp_ref[...]    # (1, TK) int32
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    ok = (kvp >= 0) & (kvp <= pos_b)          # filled & causal
    if window:
        ok &= (pos_b - kvp) < window          # sliding-window local
    s = jnp.where(ok, s, NEG)                 # (1,TK) broadcasts to (G,TK)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # zero masked probs explicitly: a tile with NO valid slot would
    # otherwise yield exp(NEG - NEG) = 1 for every masked entry
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_scr[...] = m_new


def _kernel(pos_ref, q_ref, k_ref, v_ref, kvp_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale, window, logit_cap, kv_steps, tk, w):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos_b = pos_ref[b]

    @pl.when(live_tile(ki, pos_b, tk=tk, w=w))
    def _step():
        _online_step(q_ref, k_ref, v_ref, kvp_ref, m_scr, l_scr, acc_scr,
                     pos_b, scale=scale, window=window, logit_cap=logit_cap)

    @pl.when(ki == kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@checked(q="B H hd", k="B W K hd", v="B W K hd", kv_pos="B W:int",
         pos="B:int", ret="B H hd")
def flash_decode(q, k, v, kv_pos, pos, *, scale=None, window: int = 0,
                 logit_cap: float = 0.0, interpret: bool = False):
    """q: (B, H, hd); k, v: (B, W, K, hd) un-expanded GQA ring buffers;
    kv_pos: (B, W) int32 absolute positions (-1 = unfilled); pos: (B,)
    int32 query positions. Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, W, K, _ = k.shape
    G = H // K
    assert H == K * G, (H, K)
    # small windows run as ONE tile of W rows (Mosaic pads odd sublane
    # counts), so a 40- or 63-slot cache never degenerates to gcd slivers;
    # larger windows want 128-row tiles — the serving engine rounds its
    # cache window up to a multiple of TK so the gcd is exactly TK there
    # (the gcd fallback keeps odd direct callers correct, just slower)
    tk = W if W <= TK else math.gcd(W, TK)
    kv_steps = W // tk
    scale = scale or 1.0 / (hd ** 0.5)

    qf = q.reshape(B * K, G, hd)            # head h = kh*G + g (repeat order)
    kf = k.reshape(B, W, K * hd)            # contiguous: free view
    vf = v.reshape(B, W, K * hd)
    pos = pos.astype(jnp.int32)

    def _last_tile(pos_s, b):
        n_valid = jnp.minimum(pos_s[b] + 1, W)
        return jnp.maximum(n_valid - 1, 0) // tk

    def kv_index(b, kh, ki, pos_s):
        # clamp skipped tiles onto the last valid one: the pipeline sees an
        # unchanged block index and elides the DMA entirely
        return (b, jnp.minimum(ki, _last_tile(pos_s, b)), kh)

    def kvp_index(b, kh, ki, pos_s):
        return (b, jnp.minimum(ki, _last_tile(pos_s, b)))

    kernel = functools.partial(
        _kernel, scale=scale, window=window, logit_cap=logit_cap,
        kv_steps=kv_steps, tk=tk, w=W)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, kv_steps),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, kh, ki, pos_s: (b * K + kh, 0, 0)),
            pl.BlockSpec((1, tk, hd), kv_index),
            pl.BlockSpec((1, tk, hd), kv_index),
            pl.BlockSpec((1, tk), kvp_index),
        ],
        out_specs=pl.BlockSpec(
            (1, G, hd), lambda b, kh, ki, pos_s: (b * K + kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(pos, qf, kf, vf, kv_pos)
    return out.reshape(B, H, hd)


def _paged_kernel(pos_ref, pt_ref, q_ref, k_ref, v_ref, kvp_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, window, logit_cap,
                  kv_steps, page):
    del pt_ref  # consumed by the index maps
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos_b = pos_ref[b]
    # paged caches never wrap: logical row == absolute position, so the
    # filled prefix is exactly pos+1 rows and — unlike the ring layout,
    # where old positions scatter across every tile — a sliding window also
    # bounds the LIVE tiles from below: pages wholly before pos-window hold
    # only masked rows and are skipped (their DMAs elided by the clamped
    # index maps). The gate must match _live_tile's `first` clamp exactly,
    # or a dead tile would run on the first live page's clamped DMA and
    # double-count it — repro.analysis.kernel_verify proves the agreement.

    @pl.when(live_tile_paged(ki, pos_b, page=page, window=window))
    def _step():
        _online_step(q_ref, k_ref, v_ref, kvp_ref, m_scr, l_scr, acc_scr,
                     pos_b, scale=scale, window=window, logit_cap=logit_cap)

    @pl.when(ki == kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@checked(q="B H hd", k_pool="N page K hd", v_pool="N page K hd",
         kv_pos="N page:int", page_table="B P:int", pos="B:int",
         ret="B H hd")
def flash_decode_paged(q, k_pool, v_pool, kv_pos, page_table, pos, *,
                       scale=None, window: int = 0, logit_cap: float = 0.0,
                       interpret: bool = False):
    """Page-table-aware split-KV flash decode.

    q: (B, H, hd); k_pool, v_pool: (N, page, K, hd) shared physical pools;
    kv_pos: (N, page) int32 absolute positions (-1 = unfilled); page_table:
    (B, P) int32 physical page ids (0 = reserved null page for unallocated
    entries); pos: (B,) int32 query positions. Returns (B, H, hd).

    The grid is (B, K, P) with one KV tile per page. Both scalars are
    prefetched to SMEM: ``pos`` drives the length-aware skip exactly like
    the contiguous kernel, and ``page_table`` is consumed by the K/V/kv_pos
    index maps, which gather each grid tile's PHYSICAL page. Skipped tiles
    clamp onto the slot's last live page so the pipelined DMA re-targets an
    already-resident block (elided) instead of streaming dead pool lines —
    unallocated pages are never fetched.
    """
    B, H, hd = q.shape
    N, page, K, _ = k_pool.shape
    P = page_table.shape[1]
    G = H // K
    assert H == K * G, (H, K)
    scale = scale or 1.0 / (hd ** 0.5)

    qf = q.reshape(B * K, G, hd)
    kf = k_pool.reshape(N, page, K * hd)
    vf = v_pool.reshape(N, page, K * hd)
    pos = pos.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)

    def _live_tile(pos_s, b, ki):
        # clamp ki into the slot's live page range; with a sliding window
        # the live range is two-sided (see _paged_kernel)
        last = jnp.maximum(pos_s[b], 0) // page
        first = 0
        if window:
            first = jnp.maximum(pos_s[b] - window + 1, 0) // page
        return jnp.clip(ki, first, last)

    def kv_index(b, kh, ki, pos_s, pt_s):
        return (pt_s[b, _live_tile(pos_s, b, ki)], 0, kh)

    def kvp_index(b, kh, ki, pos_s, pt_s):
        return (pt_s[b, _live_tile(pos_s, b, ki)], 0)

    kernel = functools.partial(
        _paged_kernel, scale=scale, window=window, logit_cap=logit_cap,
        kv_steps=P, page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec((1, G, hd),
                         lambda b, kh, ki, pos_s, pt_s: (b * K + kh, 0, 0)),
            pl.BlockSpec((1, page, hd), kv_index),
            pl.BlockSpec((1, page, hd), kv_index),
            pl.BlockSpec((1, page), kvp_index),
        ],
        out_specs=pl.BlockSpec(
            (1, G, hd), lambda b, kh, ki, pos_s, pt_s: (b * K + kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(pos, page_table, qf, kf, vf, kv_pos)
    return out.reshape(B, H, hd)


def decode_attn_accounting(cfg, batch: int, max_len: int,
                           mean_len: float) -> dict:
    """Analytic per-decode-step HBM traffic + FLOPs of the two attention
    paths, for the serving bench's no-TPU report. The jnp fallback reads the
    FULL cache window every step; flash-decode reads the un-expanded filled
    prefix rounded UP to its actual tile granularity (the same tk-selection
    rule as :func:`flash_decode` — a window <= TK is one tile, so nothing is
    skipped there and the ratio is honestly 1.0). At 128-row tiles the
    jnp/pallas byte ratio approaches ``max_len / mean_len``; the GQA expand
    ratio H/K no longer separates the paths (post-grouped-einsum both read
    K heads), so the remaining gap is pure length-awareness.
    """
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    itemsize = jnp.dtype(cfg.dtype).itemsize
    kv_row = 2 * K * hd * itemsize                       # one k+v cache row
    tk = max_len if max_len <= TK else math.gcd(max_len, TK)
    mean_valid = min(mean_len, max_len)
    tiled_valid = -(-int(mean_valid) // tk) * tk         # ceil to whole tiles
    flops_per_row = 2 * 2 * H * hd                       # qk^T + pv, per row
    return {
        "jnp_bytes_per_step": batch * max_len * kv_row,
        "pallas_bytes_per_step": batch * tiled_valid * kv_row,
        "jnp_flops_per_step": batch * max_len * flops_per_row,
        "pallas_flops_per_step": batch * tiled_valid * flops_per_row,
        "byte_ratio": max_len / max(tiled_valid, 1),
        "kv_tile": tk,
        "gqa_group": H // K,
    }
