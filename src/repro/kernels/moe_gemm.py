"""Pallas TPU kernel: ragged grouped GEMM for MoE expert compute.

MegaBlocks-adapted for TPU (DESIGN.md §3): tokens arrive sorted by expert and
padded so every row tile of ``TILE_N`` rows belongs to exactly one expert.
The per-tile expert id is scalar-prefetched into SMEM and drives the weight
BlockSpec index map, so each grid step streams exactly one (d, TILE_F) slice
of one expert's weights HBM->VMEM and issues a single MXU matmul.

VMEM working set per step: TILE_N*d (x) + d*TILE_F (w) + TILE_N*TILE_F (y),
bf16 — with TILE_N = TILE_F = 128 and d = 5120 that is ~2.6 MB, well inside
the ~16 MB/core budget; both matmul dims are 128-aligned for the MXU.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.contracts import checked
from repro.kernels._compat import CompilerParams as _CompilerParams

TILE_N = 128
TILE_F = 128


def _kernel(tile_expert_ref, x_ref, w_ref, y_ref):
    # x_ref: (TILE_N, d); w_ref: (1, d, TILE_F); y_ref: (TILE_N, TILE_F)
    y_ref[...] = jnp.dot(
        x_ref[...], w_ref[0],
        preferred_element_type=jnp.float32).astype(y_ref.dtype)


def _tile(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (128-aligned at real scale)."""
    import math

    return math.gcd(n, pref)


@checked(x_pad="N d", w="E d F", tile_expert="T:int", ret="N F")
def grouped_matmul_padded(x_pad, w, tile_expert, *, interpret: bool = False):
    """x_pad: (N_pad, d) rows sorted+padded per expert; w: (E, d, F);
    tile_expert: (N_pad // TILE_N,) int32. Returns (N_pad, F)."""
    n_pad, d = x_pad.shape
    e, _, f = w.shape
    tile_f = _tile(f, TILE_F)
    assert n_pad % TILE_N == 0, n_pad
    grid = (n_pad // TILE_N, f // tile_f)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, d), lambda i, j, te: (i, 0)),
            pl.BlockSpec((1, d, tile_f), lambda i, j, te: (te[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_N, tile_f), lambda i, j, te: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, f), x_pad.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(tile_expert, x_pad, w)


def padded_layout(group_sizes, num_tokens: int):
    """Static-shape padded layout for a ragged batch.

    Returns (dest_idx (N,), tile_expert (n_tiles,), n_pad) where n_pad =
    num_tokens rounded up + one extra tile per expert (static upper bound).
    dest_idx maps sorted token t to its padded row.
    """
    e = group_sizes.shape[0]
    gs = group_sizes.astype(jnp.int32)
    padded = ((gs + TILE_N - 1) // TILE_N) * TILE_N
    pad_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    raw_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(gs)[:-1].astype(jnp.int32)])
    # static upper bound on padded length
    n_pad = ((num_tokens + TILE_N - 1) // TILE_N) * TILE_N + e * TILE_N
    t = jnp.arange(num_tokens, dtype=jnp.int32)
    expert_of = jnp.searchsorted(jnp.cumsum(gs), t, side="right").astype(jnp.int32)
    dest_idx = pad_off[expert_of] + (t - raw_off[expert_of])
    tile_start = jnp.arange(n_pad // TILE_N, dtype=jnp.int32) * TILE_N
    tile_expert = jnp.searchsorted(jnp.cumsum(padded), tile_start,
                                   side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, e - 1)
    return dest_idx, tile_expert, n_pad
