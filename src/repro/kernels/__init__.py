"""Pallas TPU kernels for the framework's compute hot-spots:

  moe_gemm        — ragged grouped GEMM (MoE expert FFN), scalar-prefetched
                    per-tile expert ids (MegaBlocks adapted to the MXU)
  flash_attention — causal blocked online-softmax attention
  fused_ffn       — fused SwiGLU/GeGLU (no (M, F) hidden in HBM)

``ops.py`` holds the jit'd public wrappers (+custom VJPs); ``ref.py`` the
pure-jnp oracles every kernel is allclose-tested against.
"""
