"""Pallas TPU kernels for the framework's compute hot-spots, and where each
one is wired into the model forward:

  moe_gemm        — ragged grouped GEMM (MoE expert FFN), scalar-prefetched
                    per-tile expert ids (MegaBlocks adapted to the MXU);
                    drives ``moe_forward(mode="pallas")``
  flash_attention — causal blocked online-softmax attention, GQA-native
                    (K/V stay at K heads; the BlockSpec index map folds each
                    query head onto its KV group); serves the bucketed
                    batched PREFILL path under ``cfg.attn_impl="pallas"``
                    (repro.models.attention.attention_forward)
  flash_decode    — length-aware split-KV GQA decode attention over the
                    ring-buffered KV cache: per-slot lengths are
                    scalar-prefetched and tiles past each slot's filled
                    prefix are skipped; ring ``kv_pos`` masking, sliding
                    window, and logit softcap are fused in-kernel. Serves
                    EVERY decode step under ``cfg.attn_impl="pallas"``
                    (repro.models.attention.decode_attention — the serving
                    engine's hot path)
  fused_ffn       — fused SwiGLU/GeGLU (no (M, F) hidden in HBM)

``ops.py`` holds the jit'd public wrappers (custom VJPs for the training
kernels; flash_decode is inference-only and VJP-free); ``ref.py`` the
pure-jnp oracles every kernel is allclose-tested against.
"""
