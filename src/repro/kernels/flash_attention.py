"""Pallas TPU kernel: flash attention prefill (blocked online softmax).

Grid (B*H, S/TQ, S/TK) with the key dimension innermost ("arbitrary"
semantics — it accumulates). Running max / denominator / accumulator live in
VMEM scratch across the k steps of one (bh, q) cell; the output tile is
written once on the final k step. Tiles fully outside the mask (above the
causal diagonal, or below the sliding-window band) are skipped via @pl.when,
and the K/V index maps clamp skipped steps onto the nearest live tile so the
elided DMAs never fetch dead data (the DMA-eliding convention checked by
repro.analysis.kernel_verify).

Sliding-window (``window > 0``: query ``q`` attends keys in
``[q-window+1, q]``) and tanh logit soft-capping (``logit_cap > 0``,
gemma2-style, applied before masking like repro.models.attention._attend)
are fused in-kernel, so local-attention layers take this path instead of
the jnp fallback. Masked logits' probabilities are zeroed explicitly —
a windowed row's first live tile can be fully masked for that row, where
``exp(NEG - NEG) = 1`` would otherwise corrupt the denominator.

GQA is native: k/v may carry K <= H heads (K | H). The folded K/V batch is
(B*K, S, hd) and the K/V BlockSpec index map sends query-head cell ``bh`` to
KV row ``bh // (H/K)`` — each KV tile is streamed once per head GROUP, never
expanded to H heads in HBM. This is the prefill path behind
``cfg.attn_impl="pallas"`` (see repro.models.attention.attention_forward).

VMEM per step: TQ*hd (q) + 2*TK*hd (k,v) + TQ*TK logits + TQ*hd f32 acc —
~0.6 MB at TQ=TK=128, hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.contracts import checked
from repro.kernels._compat import CompilerParams as _CompilerParams

TQ = 128
TK = 128
NEG = -2.0e38


def live_tile(qi, ki, *, tq, tk, causal, window=0):
    """Mask-aware tile skip. The (qi, ki) tile is live iff some (q, k) pair
    in it survives the mask: causally, the highest query row ``qi*tq+tq-1``
    must reach the lowest key column ``ki*tk``; under a sliding window, the
    highest key column ``ki*tk+tk-1`` must reach the lowest row's window
    start ``qi*tq - window + 1``. Equivalently ``lo(qi) <= ki <= hi(qi)``
    with ``lo = max(qi*tq - window + 1, 0) // tk`` and
    ``hi = (qi*tq + tq - 1) // tk`` — the clamp bounds the index maps use.
    Defined at module level so the host-side contract verifier
    (repro.analysis.kernel_verify) checks the same gate the kernel runs."""
    live = (qi * tq + tq - 1 >= ki * tk) if causal else True
    if window:
        in_band = ki * tk + tk - 1 >= qi * tq - (window - 1)
        live = (live & in_band) if causal else in_band
    return live


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
            causal, window, logit_cap, kv_steps, tq=TQ, tk=TK):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = live_tile(qi, ki, tq=tq, tk=tk, causal=causal, window=window)

    @pl.when(run)
    def _step():
        q = q_ref[0]  # (TQ, hd)
        k = k_ref[0]  # (TK, hd)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        ok = None
        if causal or window:
            q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            ok = (q_pos >= k_pos) if causal else (q_pos == q_pos)
            if window:
                ok = ok & (q_pos - k_pos < window)
            s = jnp.where(ok, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        if ok is not None:
            # a row can be fully masked in a live tile (windowed first tile):
            # there m_new stays NEG and exp(NEG - NEG) = 1 — zero explicitly
            p = jnp.where(ok, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jnp.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@checked(q="B S H hd", k="B S K hd", v="B S K hd", ret="B S H hd")
def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    window: int = 0, logit_cap: float = 0.0,
                    interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, K, hd) with K | H (un-expanded GQA).
    ``window > 0`` restricts query q to keys [q-window+1, q];
    ``logit_cap > 0`` applies tanh soft-capping to the scaled logits.
    Returns (B, S, H, hd)."""
    import math

    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    assert H == K * G, (H, K)
    tq = math.gcd(S, TQ)
    tk = math.gcd(S, TK)
    scale = scale or 1.0 / (hd ** 0.5)
    # fold batch and heads: q (B*H, S, hd); k/v stay at K heads (B*K, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    kv_steps = S // tk

    def kv_index(b, i, j):
        # clamp dead k steps onto the live band [lo(i), hi(i)] so their
        # (elided) DMAs stay on data a live step fetches anyway
        lo = jnp.maximum(i * tq - (window - 1), 0) // tk if window else 0
        hi = (i * tq + tq - 1) // tk if causal else kv_steps - 1
        # query-head cell b*H+h reads KV head group (b*H+h)//G = b*K+h//G
        return (b // G, jnp.clip(j, lo, hi), 0)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, logit_cap=logit_cap,
                               kv_steps=kv_steps, tq=tq, tk=tk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // tq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd), kv_index),
            pl.BlockSpec((1, tk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
