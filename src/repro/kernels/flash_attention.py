"""Pallas TPU kernel: causal flash attention (blocked online softmax).

Grid (B*H, S/TQ, S/TK) with the key dimension innermost ("arbitrary"
semantics — it accumulates). Running max / denominator / accumulator live in
VMEM scratch across the k steps of one (bh, q) cell; the output tile is
written once on the final k step. Causal tiles above the diagonal are
skipped via @pl.when, so the kernel does ~half the work of the dense matmul.

GQA is native: k/v may carry K <= H heads (K | H). The folded K/V batch is
(B*K, S, hd) and the K/V BlockSpec index map sends query-head cell ``bh`` to
KV row ``bh // (H/K)`` — each KV tile is streamed once per head GROUP, never
expanded to H heads in HBM. This is the prefill path behind
``cfg.attn_impl="pallas"`` (see repro.models.attention.attention_forward).

VMEM per step: TQ*hd (q) + 2*TK*hd (k,v) + TQ*TK logits + TQ*hd f32 acc —
~0.6 MB at TQ=TK=128, hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.contracts import checked
from repro.kernels._compat import CompilerParams as _CompilerParams

TQ = 128
TK = 128
NEG = -2.0e38


def live_tile(qi, ki, *, tq, tk, causal):
    """Causal tile skip: the (qi, ki) tile is live iff its highest query row
    ``qi*tq + tq - 1`` can attend its lowest key column ``ki*tk``. Defined at
    module level so the host-side contract verifier
    (repro.analysis.kernel_verify) checks the same gate the kernel runs."""
    return (qi * tq + tq - 1 >= ki * tk) if causal else True


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
            causal, kv_steps, tq=TQ, tk=TK):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = live_tile(qi, ki, tq=tq, tk=tk, causal=causal)

    @pl.when(run)
    def _step():
        q = q_ref[0]  # (TQ, hd)
        k = k_ref[0]  # (TK, hd)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jnp.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@checked(q="B S H hd", k="B S K hd", v="B S K hd", ret="B S H hd")
def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, K, hd) with K | H (un-expanded GQA).
    Returns (B, S, H, hd)."""
    import math

    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    assert H == K * G, (H, K)
    tq = math.gcd(S, TQ)
    tk = math.gcd(S, TK)
    scale = scale or 1.0 / (hd ** 0.5)
    # fold batch and heads: q (B*H, S, hd); k/v stay at K heads (B*K, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    kv_steps = S // tk

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               kv_steps=kv_steps, tq=tq, tk=tk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // tq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            # query-head cell b*H+h reads KV head group (b*H+h)//G = b*K+h//G
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
