"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x_sorted, w, group_sizes):
    """x_sorted: (N, d) sorted by expert; w: (E, d, F); returns (N, F)."""
    return jax.lax.ragged_dot(x_sorted, w, group_sizes.astype(jnp.int32))


def grouped_ffn_ref(x_sorted, wg, wu, wd, group_sizes, act: str = "silu"):
    from repro.models.layers import activation

    f = activation(act)
    gs = group_sizes.astype(jnp.int32)
    h = f(jax.lax.ragged_dot(x_sorted, wg, gs)) * jax.lax.ragged_dot(x_sorted, wu, gs)
    return jax.lax.ragged_dot(h, wd, gs)


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q,k,v: (B, S, H, hd) -> (B, S, H, hd), fp32 softmax."""
    B, S, H, hd = q.shape
    scale = scale or 1.0 / (hd ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None, None], logits, -2.0e38)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def fused_ffn_ref(x, wg, wu, wd, act: str = "silu"):
    from repro.models.layers import activation

    f = activation(act)
    return (f(x @ wg) * (x @ wu)) @ wd
