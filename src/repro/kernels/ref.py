"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x_sorted, w, group_sizes):
    """x_sorted: (N, d) sorted by expert; w: (E, d, F); returns (N, F)."""
    return jax.lax.ragged_dot(x_sorted, w, group_sizes.astype(jnp.int32))


def grouped_ffn_ref(x_sorted, wg, wu, wd, group_sizes, act: str = "silu"):
    from repro.models.layers import activation

    f = activation(act)
    gs = group_sizes.astype(jnp.int32)
    h = f(jax.lax.ragged_dot(x_sorted, wg, gs)) * jax.lax.ragged_dot(x_sorted, wu, gs)
    return jax.lax.ragged_dot(h, wd, gs)


def attention_ref(q, k, v, *, causal: bool = True, scale=None,
                  window: int = 0, logit_cap: float = 0.0):
    """q: (B, S, H, hd); k, v: (B, S, K, hd), K | H (GQA: each kv head
    serves H/K query heads). Returns (B, S, H, hd), fp32 softmax. Softcap
    applies BEFORE masking; ``window`` keeps only the last ``window``
    positions (q - kv < window) — mirrors the prefill kernel exactly."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    scale = scale or 1.0 / (hd ** 0.5)
    qg = q.reshape(B, S, K, H // K, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if logit_cap:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    mask = None
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
    if window:
        pos = jnp.arange(S)
        band = pos[:, None] - pos[None, :] < window
        mask = band if mask is None else (mask & band)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -2.0e38)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, S, H, hd)


def flash_decode_ref(q, k, v, kv_pos, pos, *, scale=None, window: int = 0,
                     logit_cap: float = 0.0):
    """Decode-step oracle. q: (B, H, hd); k, v: (B, W, K, hd) ring buffers;
    kv_pos: (B, W) absolute positions (-1 = unfilled); pos: (B,) query
    positions. Mask = filled & causal (& sliding window); softcap before
    masking — mirrors repro.models.attention decode semantics exactly."""
    B, H, hd = q.shape
    K = k.shape[2]
    scale = scale or 1.0 / (hd ** 0.5)
    qg = q.reshape(B, K, H // K, hd)
    logits = jnp.einsum("bkgd,bwkd->bkgw", qg, k).astype(jnp.float32) * scale
    if logit_cap:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    ok = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window:
        ok &= (pos[:, None] - kv_pos) < window
    logits = jnp.where(ok[:, None, None, :], logits, -2.0e38)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgw,bwkd->bkgd", w, v)
    return out.reshape(B, H, hd)


def flash_decode_paged_ref(q, k_pool, v_pool, kv_pos, page_table, pos, *,
                           scale=None, window: int = 0,
                           logit_cap: float = 0.0):
    """Paged decode oracle: gather each slot's logical KV view through its
    page table (unallocated entries hit the null page, whose kv_pos is -1),
    then reduce to the contiguous ring oracle."""
    from repro.models.kvcache import gather_paged_kv

    k = gather_paged_kv(k_pool, page_table)      # (B, P*page, K, hd)
    v = gather_paged_kv(v_pool, page_table)
    kvp = gather_paged_kv(kv_pos, page_table)    # (B, P*page)
    return flash_decode_ref(q, k, v, kvp, pos, scale=scale, window=window,
                            logit_cap=logit_cap)


def fused_ffn_ref(x, wg, wu, wd, act: str = "silu"):
    from repro.models.layers import activation

    f = activation(act)
    return (f(x @ wg) * (x @ wu)) @ wd
