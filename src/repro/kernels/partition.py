"""Partitioning story for the Pallas serving kernels under a mesh.

GSPMD has no partitioning rule for ``pallas_call`` — naively tracing a
kernel launch inside a sharded jit makes the partitioner give up (or
all-gather the world). Instead, every serving kernel launch goes through a
``shard_map`` wrapper so the kernel runs **per shard** with shapes GSPMD
never has to reason about. Three strategies, picked per model config to
align with :func:`repro.parallel.sharding.choose_kv_spec` (so the engine's
cache placement and the kernel's expected layout agree, and no resharding
happens on the hot path):

``heads``  — ``num_kv_heads % tp == 0``. K/V (and q, via the GQA head
    order ``h = kh*G + g``: a contiguous block of ``H/tp`` query heads is
    exactly the ``K/tp`` kv-head groups of one shard) are sharded over the
    head dim. Attention is independent per head, so each shard runs the
    unmodified kernel on its slice — zero collectives.

``gather`` — ``head_dim % tp == 0`` (the small-config fallback of
    ``choose_kv_spec``). K/V live sharded over ``hd`` at rest; inside the
    shard_map each shard ``all_gather``\\ s the head_dim (tiled) and runs
    the full kernel. Memory stays sharded; compute is replicated — the
    right trade at decode batch sizes, where KV residency dominates.

``replicated`` — neither divides. Everything is replicated and each shard
    runs the identical full launch (out_specs replicated).

When no mesh is in context / ``tp == 1`` / ``pc`` is None, the wrappers
fall through to the plain jitted ops — single-device callers never pay for
the indirection. Batch dims use the ``pc.dp`` axis when the mesh carries
it (serving meshes are ``(data=1, model=ep)``), matching ``cache_pspecs``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.flash_decode import (
    flash_decode_paged as _flash_decode_paged,
)
from repro.parallel.compat import shard_map_compat
from repro.parallel.sharding import get_context_mesh


class KernelSharding(NamedTuple):
    mesh: object
    axis: str          # the tp/ep mesh axis the kernel is partitioned over
    tp: int
    mode: str          # 'heads' | 'gather' | 'replicated'
    batch_axis: object  # pc.dp when the mesh carries it, else None


def kernel_sharding(cfg, pc) -> Optional[KernelSharding]:
    """The partitioning strategy for this (config, ParallelConfig, context
    mesh) triple, or None when the plain single-device launch applies."""
    if pc is None or pc.tp_axis is None:
        return None
    mesh = get_context_mesh()
    if mesh is None or pc.tp_axis not in mesh.axis_names:
        return None
    tp = int(mesh.shape[pc.tp_axis])
    if tp == 1:
        return None
    if cfg.num_kv_heads % tp == 0:
        mode = "heads"
    elif cfg.head_dim % tp == 0:
        mode = "gather"
    else:
        mode = "replicated"
    dp_axes = pc.dp_axes if isinstance(pc.dp, tuple) else (pc.dp,)
    b = pc.dp if all(a in mesh.axis_names for a in dp_axes) else None
    return KernelSharding(mesh, pc.tp_axis, tp, mode, b)


def _gathered(fn, axis, kv_argnums, kv_axis):
    """Wrap ``fn`` so the kv operands all-gather their sharded dim first."""

    def wrapped(*args):
        args = list(args)
        for i in kv_argnums:
            args[i] = jax.lax.all_gather(args[i], axis, axis=kv_axis,
                                         tiled=True)
        return fn(*args)

    return wrapped


def sharded_flash_decode(cfg, pc, q, k, v, kv_pos, pos, *, scale=None,
                         window: int = 0, logit_cap: float = 0.0):
    """flash_decode under the context mesh (per-shard shard_map launch);
    plain jitted op when unsharded. Same operand contract as
    :func:`repro.kernels.ops.flash_decode`."""
    ks = kernel_sharding(cfg, pc)
    if ks is None:
        return ops.flash_decode(q, k, v, kv_pos, pos, scale=scale,
                                window=window, logit_cap=logit_cap)
    t, b = ks.axis, ks.batch_axis
    kern = functools.partial(_flash_decode, scale=scale, window=window,
                             logit_cap=logit_cap, interpret=ops.INTERPRET)
    if ks.mode == "heads":
        in_specs = (P(b, t, None), P(b, None, t, None), P(b, None, t, None),
                    P(b, None), P(b))
        out_specs = P(b, t, None)
        fn = kern
    else:
        kv = P(b, None, None, t if ks.mode == "gather" else None)
        in_specs = (P(b, None, None), kv, kv, P(b, None), P(b))
        out_specs = P(b, None, None)
        fn = (_gathered(kern, t, (1, 2), 3)
              if ks.mode == "gather" else kern)
    return shard_map_compat(fn, mesh=ks.mesh, in_specs=in_specs,
                            out_specs=out_specs)(q, k, v, kv_pos, pos)


def sharded_flash_decode_paged(cfg, pc, q, k_pool, v_pool, kv_pos,
                               page_table, pos, *, scale=None,
                               window: int = 0, logit_cap: float = 0.0):
    """flash_decode_paged under the context mesh. Page pools are sharded
    over heads (or head_dim) only — the page dim is a logical address space
    shared by all shards, so ``kv_pos``/``page_table`` stay replicated
    (batch over dp)."""
    ks = kernel_sharding(cfg, pc)
    if ks is None:
        return ops.flash_decode_paged(q, k_pool, v_pool, kv_pos, page_table,
                                      pos, scale=scale, window=window,
                                      logit_cap=logit_cap)
    t, b = ks.axis, ks.batch_axis
    kern = functools.partial(_flash_decode_paged, scale=scale, window=window,
                             logit_cap=logit_cap, interpret=ops.INTERPRET)
    if ks.mode == "heads":
        in_specs = (P(b, t, None), P(None, None, t, None),
                    P(None, None, t, None), P(None, None), P(b, None), P(b))
        out_specs = P(b, t, None)
        fn = kern
    else:
        pool = P(None, None, None, t if ks.mode == "gather" else None)
        in_specs = (P(b, None, None), pool, pool, P(None, None),
                    P(b, None), P(b))
        out_specs = P(b, None, None)
        fn = (_gathered(kern, t, (1, 2), 3)
              if ks.mode == "gather" else kern)
    return shard_map_compat(fn, mesh=ks.mesh, in_specs=in_specs,
                            out_specs=out_specs)(q, k_pool, v_pool, kv_pos,
                                                 page_table, pos)


def sharded_flash_attention(cfg, pc, q, k, v, *, causal: bool = True,
                            scale=None, window: int = 0,
                            logit_cap: float = 0.0):
    """flash prefill under the context mesh. Same operand contract as
    :func:`repro.kernels.ops.flash_attention`."""
    ks = kernel_sharding(cfg, pc)
    if ks is None:
        return ops.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window, logit_cap=logit_cap)
    t, b = ks.axis, ks.batch_axis
    kern = functools.partial(_flash, causal=causal, scale=scale,
                             window=window, logit_cap=logit_cap,
                             interpret=ops.INTERPRET)
    if ks.mode == "heads":
        in_specs = (P(b, None, t, None),) * 3
        out_specs = P(b, None, t, None)
        fn = kern
    else:
        kv = P(b, None, None, t if ks.mode == "gather" else None)
        in_specs = (P(b, None, None, None), kv, kv)
        out_specs = P(b, None, None, None)
        fn = (_gathered(kern, t, (1, 2), 3)
              if ks.mode == "gather" else kern)
    return shard_map_compat(fn, mesh=ks.mesh, in_specs=in_specs,
                            out_specs=out_specs)(q, k, v)
