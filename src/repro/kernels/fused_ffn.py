"""Pallas TPU kernel: fused SwiGLU/GeGLU FFN.

Computes y = (act(x @ Wg) * (x @ Wu)) @ Wd without ever materialising the
(M, F) hidden activation in HBM: grid (M/TM, F/TF) with the F dimension
innermost accumulating into a (TM, d) f32 VMEM scratch. Each step loads one
(d, TF) slice of Wg/Wu and one (TF, d) slice of Wd.

VMEM per step: TM*d (x) + 2*d*TF + TF*d + TM*d f32 acc. With TM=TF=128,
d=4096: ~5.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.contracts import checked
from repro.kernels._compat import CompilerParams as _CompilerParams

TM = 128
TF = 128


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, y_ref, acc_scr, *, act, f_steps):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]  # (TM, d)
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    if act == "silu":
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g, approximate=True) * u
    acc_scr[...] += jnp.dot(h.astype(x.dtype), wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(fi == f_steps - 1)
    def _finish():
        y_ref[...] = acc_scr[...].astype(y_ref.dtype)


@checked(x="M d", wg="d F", wu="d F", wd="F d", ret="M d")
def fused_ffn(x, wg, wu, wd, act: str = "silu", *, interpret: bool = False):
    """x: (M, d); wg/wu: (d, F); wd: (F, d) -> (M, d)."""
    import math

    m, d = x.shape
    _, f = wg.shape
    tm = math.gcd(m, TM)
    tf = math.gcd(f, TF)
    f_steps = f // tf

    kernel = functools.partial(_kernel, act=act, f_steps=f_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // tm, f_steps),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, tf), lambda i, j: (0, j)),
            pl.BlockSpec((d, tf), lambda i, j: (0, j)),
            pl.BlockSpec((tf, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((tm, d), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, wg, wu, wd)
