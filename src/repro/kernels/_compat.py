"""jax version compat for the Pallas kernels.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` around 0.5; resolve
whichever exists once so every kernel imports on every toolchain the repo
targets.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
