"""Jit'd public wrappers around the Pallas kernels, with custom VJPs.

On CPU (this container) the kernels run in ``interpret=True`` mode; on TPU
they compile natively. ``INTERPRET`` is derived from the default backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import moe_gemm as mg
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.flash_decode import flash_decode_paged as _flash_decode_paged
from repro.kernels.fused_ffn import fused_ffn as _ffn

INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Grouped matmul (ragged, sorted-by-expert)
# ---------------------------------------------------------------------------


def _grouped_matmul_fwd_impl(x_sorted, w, group_sizes):
    n, d = x_sorted.shape
    dest_idx, tile_expert, n_pad = mg.padded_layout(group_sizes, n)
    x_pad = jnp.zeros((n_pad, d), x_sorted.dtype).at[dest_idx].set(x_sorted)
    y_pad = mg.grouped_matmul_padded(x_pad, w, tile_expert, interpret=INTERPRET)
    return jnp.take(y_pad, dest_idx, axis=0), (x_pad, dest_idx, tile_expert)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def grouped_matmul(x_sorted, w, group_sizes):
    """x_sorted: (N, d) rows sorted by expert id; w: (E, d, F);
    group_sizes: (E,) int32 summing to N. Returns (N, F)."""
    y, _ = _grouped_matmul_fwd_impl(x_sorted, w, group_sizes)
    return y


def _gm_fwd(x_sorted, w, group_sizes):
    y, (x_pad, dest_idx, tile_expert) = _grouped_matmul_fwd_impl(
        x_sorted, w, group_sizes)
    return y, (x_pad, dest_idx, tile_expert, w, group_sizes)


def _gm_bwd(res, dy):
    x_pad, dest_idx, tile_expert, w, group_sizes = res
    n_pad, d = x_pad.shape
    e, _, f = w.shape
    dy_pad = jnp.zeros((n_pad, f), dy.dtype).at[dest_idx].set(dy)
    # dx = dy @ w^T  (same grouped layout, transposed weights)
    wt = jnp.swapaxes(w, 1, 2)  # (E, F, d)
    dx_pad = mg.grouped_matmul_padded(dy_pad, wt, tile_expert,
                                      interpret=INTERPRET)
    dx = jnp.take(dx_pad, dest_idx, axis=0)
    # dw[e] = x_e^T dy_e: per-tile outer products segment-summed by expert
    n_tiles = n_pad // mg.TILE_N
    xt = x_pad.reshape(n_tiles, mg.TILE_N, d)
    dyt = dy_pad.reshape(n_tiles, mg.TILE_N, f)
    per_tile = jnp.einsum("tnd,tnf->tdf", xt.astype(jnp.float32),
                          dyt.astype(jnp.float32))
    dw = jax.ops.segment_sum(per_tile, tile_expert, num_segments=e)
    return dx, dw.astype(w.dtype), None


grouped_matmul.defvjp(_gm_fwd, _gm_bwd)


def grouped_ffn(x_sorted, wg, wu, wd, group_sizes, act: str = "silu"):
    """Grouped expert FFN built from three grouped matmuls; elementwise glue
    is fused by XLA around the kernels."""
    from repro.models.layers import activation

    f = activation(act)
    h = f(grouped_matmul(x_sorted, wg, group_sizes)) * grouped_matmul(
        x_sorted, wu, group_sizes)
    return grouped_matmul(h, wd, group_sizes)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "window", "logit_cap"))
def flash_attention(q, k, v, causal: bool = True, scale=None,
                    window: int = 0, logit_cap: float = 0.0):
    """q: (B,S,H,hd); k,v: (B,S,K,hd) un-expanded GQA (K | H). ``window``
    (sliding-window length) and ``logit_cap`` (tanh soft-cap) are fused
    in-kernel."""
    return _flash(q, k, v, causal=causal, scale=scale, window=window,
                  logit_cap=logit_cap, interpret=INTERPRET)


# ---------------------------------------------------------------------------
# Flash decode (serving hot path; inference-only, no VJP)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("scale", "window", "logit_cap"))
def flash_decode(q, k, v, kv_pos, pos, *, scale=None, window: int = 0,
                 logit_cap: float = 0.0):
    """Length-aware split-KV GQA decode attention over a ring-buffered KV
    cache. q: (B,H,hd); k,v: (B,W,K,hd); kv_pos: (B,W) int32 (-1 =
    unfilled); pos: (B,) int32. Returns (B,H,hd)."""
    return _flash_decode(q, k, v, kv_pos, pos, scale=scale, window=window,
                         logit_cap=logit_cap, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("scale", "window", "logit_cap"))
def flash_decode_paged(q, k_pool, v_pool, kv_pos, page_table, pos, *,
                       scale=None, window: int = 0, logit_cap: float = 0.0):
    """Page-table-aware flash decode over the shared KV pool. q: (B,H,hd);
    k_pool,v_pool: (N,page,K,hd); kv_pos: (N,page) int32 (-1 = unfilled);
    page_table: (B,P) int32 (0 = null page); pos: (B,) int32."""
    return _flash_decode_paged(q, k_pool, v_pool, kv_pos, page_table, pos,
                               scale=scale, window=window,
                               logit_cap=logit_cap, interpret=INTERPRET)


# ---------------------------------------------------------------------------
# Fused dense FFN
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("act",))
def fused_ffn(x, wg, wu, wd, act: str = "silu"):
    shape = x.shape
    y = _ffn(x.reshape(-1, shape[-1]), wg, wu, wd, act, interpret=INTERPRET)
    return y.reshape(shape)
