"""Deterministic synthetic token pipeline (C4 stand-in).

The stream is a mixture of ``n_domains`` latent domains. Each domain owns a
token BAND of size V//n_domains and follows a noisy affine-congruential
transition inside its band: ``next = band_d + (a_d*(cur-band_d) + b_d + eps)
mod |band|``. Band ownership gives MoE experts a strong reason to specialise
per domain (router sees band-specific embeddings), which is exactly the
structure HC-SMoE's output-based clustering exploits — the benchmarks train
a tiny MoE on this and reproduce the paper's qualitative ordering.

Fully deterministic in (seed, step): the pipeline is checkpointable by
storing the integer step, and shard-aware batching slices the global batch
by (dp_rank, dp_size).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_domains: int = 8
    noise: int = 3
    # restrict sequences to a subset of the domain ids (eval "tasks" sample
    # different domain mixtures of the SAME transition tables)
    domain_subset: tuple = ()

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self.band = max(2, self.vocab_size // self.n_domains)
        self.a = 1 + 2 * rng.randint(1, max(2, self.band // 2),
                                     self.n_domains)  # odd -> mixing
        self.b = rng.randint(0, self.band, self.n_domains)

    def batch(self, step: int, *, dp_rank: int = 0, dp_size: int = 1):
        """Returns {"tokens","labels"} (local_batch, seq_len) int32."""
        assert self.global_batch % dp_size == 0
        local = self.global_batch // dp_size
        out = np.empty((local, self.seq_len), np.int64)
        choices = (list(self.domain_subset) if self.domain_subset
                   else list(range(self.n_domains)))
        for i in range(local):
            g = dp_rank * local + i
            rng = np.random.RandomState(
                (self.seed * 1_000_003 + step * 4099 + g) % (2**31 - 1))
            d = choices[rng.randint(len(choices))]
            a, b = int(self.a[d]), int(self.b[d])
            band0 = d * self.band
            cur = rng.randint(self.band)
            for t in range(self.seq_len):
                out[i, t] = band0 + cur
                cur = (a * cur + b + rng.randint(self.noise)) % self.band
        tokens = out.astype(np.int32)
        return {"tokens": tokens, "labels": tokens}


def calibration_batches(cfg, *, n_seqs: int = 32, seq_len: int = 2048,
                        batch: int = 4, seed: int = 1234):
    """The paper's calibration protocol (32 x 2048 C4 tokens), scaled by
    args. Returns a list of model-input dicts."""
    import jax.numpy as jnp

    stream = TokenStream(cfg.vocab_size, seq_len, batch, seed=seed)
    n_batches = max(1, n_seqs // batch)
    out = []
    for s in range(n_batches):
        b = stream.batch(s)
        d = {"tokens": jnp.asarray(b["tokens"])}
        if cfg.family == "vlm":
            rngk = np.random.RandomState(seed + s)
            d["patch_embeds"] = jnp.asarray(
                rngk.randn(batch, cfg.num_patch_tokens, cfg.d_model) * 0.02,
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        if cfg.family == "encdec":
            rngk = np.random.RandomState(seed + s)
            d["src_frames"] = jnp.asarray(
                rngk.randn(batch, seq_len, cfg.d_model) * 0.02,
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        out.append(d)
    return out
