from repro.data.synthetic import TokenStream, calibration_batches  # noqa: F401
