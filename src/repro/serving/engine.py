"""Batched serving engine: jit'd prefill + decode with KV cache, greedy or
temperature sampling, and a continuous-batching scheduler (slot-based).

The merged-expert serving path is first-class: pass HC-SMoE-merged params and
the engine runs them unchanged (group_map routing) — the paper's deployment
story. Decode is a single fused step over the whole batch; finished requests
free their slot and the scheduler refills from the queue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.kvcache import init_cache


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, moe_mode: str = "ragged",
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.moe_mode = moe_mode
        self.eos_id = eos_id

        self._decode = jax.jit(partial(model.decode_step, moe_mode=moe_mode))
        self._prefill_one = jax.jit(
            partial(model.prefill, moe_mode=moe_mode, cache_max_len=max_len))

        self.cache = init_cache(self.cfg, batch_slots, max_len,
                                jnp.dtype(self.cfg.dtype))
        self.active: Dict[int, Request] = {}   # slot -> request
        self.queue: List[Request] = []
        self.last_token = np.zeros((batch_slots, 1), np.int32)
        self.slot_live = np.zeros(batch_slots, bool)

    # ------------------------------------------------------------ admission
    def submit(self, req: Request):
        self.queue.append(req)

    def _splice(self, slot: int, cache1):
        """Copy a single-request cache (batch 1) into batch slot ``slot``.

        Batch dim is 0 for "pos"/prefix leaves and 1 for stacked block
        leaves (which carry a leading n_blocks dim)."""

        def visit(path, big, one):
            top = path[0].key
            if top == "blocks":
                return big.at[:, slot].set(one[:, 0])
            return big.at[slot].set(one[0])

        self.cache = jax.tree_util.tree_map_with_path(visit, self.cache,
                                                      cache1)

    def _admit(self):
        # NOTE: prefill jit-recompiles per distinct prompt length; a
        # production deployment buckets prompt lengths (powers of two).
        for slot in range(self.slots):
            if self.slot_live[slot] or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, cache1 = self._prefill_one(
                self.params, tokens=jnp.asarray(req.prompt[None]))
            self._splice(slot, cache1)
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                len(req.prompt))
            tok = int(jnp.argmax(logits[0, -1]))
            req.generated.append(tok)
            self.last_token[slot, 0] = tok
            self.active[slot] = req
            self.slot_live[slot] = True

    # --------------------------------------------------------------- decode
    def step(self):
        """One engine step: admit waiting requests, decode one token for
        every live slot, retire finished requests."""
        self._admit()
        if not self.slot_live.any():
            return False
        logits, self.cache = self._decode(
            self.params, tokens=jnp.asarray(self.last_token),
            cache=self.cache)
        next_tokens = np.asarray(jnp.argmax(logits[:, 0], axis=-1),
                                 np.int32)
        for slot, req in list(self.active.items()):
            tok = int(next_tokens[slot])
            req.generated.append(tok)
            self.last_token[slot, 0] = tok
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                req.done = True
                del self.active[slot]
                self.slot_live[slot] = False
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        steps = 0
        while (self.queue or self.slot_live.any()) and steps < max_steps:
            self.step()
            steps += 1
        return finished
