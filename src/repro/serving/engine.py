"""Continuous-batching serving engine.

The merged-expert serving path is first-class: pass HC-SMoE-merged params
and the engine runs them unchanged (group_map routing) — the paper's
deployment story. Alternatively hand the engine an offline-computed
compression plan (``ServingConfig(merge_plan=...)``, see
:mod:`repro.core.plan` and ``launch/serve.py --merge-plan``) and it applies
the plan to the params at load time — no calibration machinery in the
serving process. Decode is a single fused jit step over the whole slot
batch; finished requests free their slot and the scheduler refills from the
FCFS queue.

Engine knobs live on :class:`ServingConfig`
(``ServingEngine(model, params, config=ServingConfig(...))``; flat kwargs
remain as a back-compat construction path, and
:meth:`ServingConfig.validate` is the single home of the few genuinely
impossible combinations). The three serving axes — **KV layout**
(contiguous/paged) × **attention backend** (jnp/pallas) × **expert
parallelism** (single-device/EP mesh) — compose freely: every engine runs
the same unified dispatch paths (``_splice_fn`` for admission splices,
``_decode_dispatch`` for the per-token step, ``_call`` entering the serving
mesh context), and each axis only swaps what it owns — the cache pytree
shape, the attention kernel, or the shardings. All eight combinations are
greedy-token-identical (tested). Engine anatomy (and the knobs that control
it):

* **Bucketed batched prefill** (``bucket_prompts``, ``min_bucket``,
  ``prefill_batch``): admission right-pads up to ``prefill_batch`` queued
  prompts to a shared power-of-two bucket and prefills them in ONE call, so
  mixed-length traffic compiles at most ``O(log2(max_len))`` prefill shapes
  (one per bucket — the batch dim is padded to a single size too). Exactness
  of right padding under causal masking is argued in
  :mod:`repro.serving.bucketing`; padded KV-cache entries are neutralised by
  setting their ``kv_pos`` to -1 (the unfilled-slot sentinel every decode
  mask honours). Architectures where padding is not exact (recurrent
  mixers, short sliding windows, enc-dec/VLM) automatically fall back to
  exact-length per-request prefill.
* **Sampling** (:mod:`repro.serving.sampling`): each :class:`Request`
  carries a :class:`SamplingParams` (temperature / top_p / seed); one
  jitted vmapped sampler draws every slot's next token with per-request
  parameters. ``temperature=0`` is greedy. Token ``i`` of a request is
  always drawn from ``fold_in(PRNGKey(seed), i)`` — deterministic across
  slot assignment and batch composition.
* **Telemetry**: every request records submit/admit/first-token/done
  timestamps (``queue_time``/``ttft``/``tokens_per_s`` properties);
  :meth:`ServingEngine.stats` aggregates them into a :class:`ServingStats`
  (throughput, mean TTFT, prefill call/compile counts, decode steps). Wall
  time accrues inside :meth:`ServingEngine.step`, so driving the engine
  step-by-step and via :meth:`ServingEngine.run` report the same clock;
  ``prefill_compilations`` counts executables compiled SINCE the last
  :meth:`ServingEngine.reset_stats` (warm-up compiles drop out of the
  post-reset window).
* **Attention backend** (``attn_impl="jnp" | "pallas"``): the decode hot
  path — one attention call per layer per generated token — either runs the
  grouped-einsum jnp fallback or the Pallas **flash-decode** kernel
  (:mod:`repro.kernels.flash_decode`): split-KV online softmax over the
  un-expanded GQA ring buffer with scalar-prefetched per-slot lengths, so
  short requests stop paying O(max_len) K/V traffic. ``attn_impl="pallas"``
  also routes eligible bucketed-prefill layers through the blocked flash
  attention kernel (power-of-two buckets tile cleanly). Greedy outputs are
  token-identical across backends (tested); on CPU the kernels run in
  interpret mode so CI exercises the same code path. Per-step decode
  latency is tracked separately (``ServingStats.decode_step_ms``) so the
  serving bench can report the backend speedup.
* **KV layout** (``kv_layout="contiguous" | "paged"``, ``kv_page_size``,
  ``kv_pages``): contiguous is the PR-3 layout — every slot owns a
  ``max_len``-row ring buffer per layer, provisioned for the worst case.
  ``"paged"`` switches to the vLLM-style shared page pool
  (:mod:`repro.models.kvcache`): fixed-size pages handed out by a host-side
  free-list :class:`~repro.models.kvcache.PageAllocator` on admission,
  grown on demand as decode crosses page boundaries, and released when a
  request retires — KV memory tracks the tokens actually resident instead
  of ``slots * max_len``. Attention reads go through the per-slot page
  table: the jnp backend gathers the logical view, ``attn_impl="pallas"``
  runs the page-table-aware flash-decode kernel (page table scalar-
  prefetched to SMEM; unallocated pages are never fetched). Greedy outputs
  are token-identical to the contiguous layout (tested). Paged serving
  requires attention-family mixers (the one rejected combination — a page
  pool has no meaning for recurrent state); it composes with EP and with
  either attention backend.
* **Chunked prefill** (``prefill_chunk``, paged layout only): prompts
  longer than ``prefill_chunk`` tokens skip the bucketed batch prefill and
  are instead prefilled chunk-by-chunk through ``model.extend`` —
  page-by-page cache writes at ONE compiled shape — interleaved with decode
  steps of the running batch, so a long prompt no longer stalls every
  in-flight request for one monolithic prefill (``ServingStats.max_step_s``
  is the stall proxy) and no power-of-two mega-bucket is compiled for it.
  Short prompts keep the bucketed path unchanged.
* **Expert-parallel serving** (``parallel=ParallelConfig(ep=True, ...)``,
  optional ``mesh``): params are placed per ``param_pspecs(..., ep=True)``
  — each device holds ``expert_bytes / ep_degree`` of every MoE stack —
  and ``_prefill``/``_decode`` are jitted with ``in_shardings`` /
  ``out_shardings`` built from those pspecs plus ``cache_pspecs_sized``,
  so the KV cache stays in its sharded steady-state across decode steps.
  Routing correctness under EP comes from the shard_map forward in
  :mod:`repro.models.moe` (replicated routing, shard-local expert GEMMs —
  design notes in :mod:`repro.parallel.sharding`). Host-side cache splices
  are re-placed onto the cache shardings by ``_place_cache()`` after every
  eager mutation (admission splice, page-table sync, page release). Expert
  stacks whose slot count does not divide the EP degree (merged models) are
  zero-padded up front via ``pad_expert_slots`` — routing can never reach
  the padded slots. K/V tensors additionally shard over the model axis when
  head count or head_dim divides it (:func:`choose_kv_spec` /
  ``cache_pspecs_sized``); the Pallas kernels then run per-shard via the
  ``shard_map`` wrappers in :mod:`repro.kernels.partition`, so pallas
  attention composes with EP on both KV layouts. Per-device footprints are
  reported by :meth:`ServingEngine.expert_bytes_per_device` and the
  ``kv_shard_degree`` / ``kv_bytes_peak_per_device`` fields of
  :meth:`ServingEngine.stats` and :meth:`ServingEngine.kv_memory`.
* **Request lifecycle under overload** (``admission="optimistic" |
  "reserve"``, ``Request.deadline_s``, :meth:`ServingEngine.cancel`,
  ``faults=FaultConfig(...)``): every request walks an explicit state
  machine (:class:`RequestStatus`: QUEUED → PREFILLING/RUNNING → one of
  FINISHED / CANCELLED / EXPIRED / FAILED). The default paged admission
  policy is **optimistic**: a request is admitted when its *resident*
  rows (prompt + already-generated tokens, plus one decode row) fit the
  free pool — not its worst case — so the pool runs at the occupancy the
  traffic actually needs. When decode growth or a prefill chunk then
  exhausts the pool, the engine **preempts** the latest-admitted resident
  request vLLM-style: its pages are released, the request rejoins the
  FRONT of the queue with its generated tokens carried along, and
  re-admission recomputes its KV by prefilling ``prompt + generated``
  through the normal (bucketed or chunked) prefill path. Because token
  ``i`` is always sampled from ``fold_in(seed, i)``, a resumed stream is
  token-identical to an unpreempted run — greedy *and* stochastic (the
  chaos-test oracle). ``admission="reserve"`` keeps the PR-4 worst-case
  reservation behavior as the conservative baseline. Per-request
  ``deadline_s`` (measured from submit) and :meth:`~ServingEngine.cancel`
  are enforced at step boundaries; a NaN/Inf logit guard
  (``logit_guard``) quarantines the offending request (FAILED) instead
  of crashing the batch; and a seeded fault-injection layer
  (:mod:`repro.serving.faults`) can force preemptions, allocator
  exhaustion, splice failures, poisoned logits, and stalled steps to
  drive every failure path deterministically. See
  docs/serving_lifecycle.md.
* **Speculative decoding** (``speculative=SpecConfig(draft_plan=...,
  k=...)``, paged layout only): a MergePlan-derived draft model — the
  paper's compression artifact applied aggressively via ``apply_plan`` at
  engine load — proposes ``k`` tokens per resident request and ONE batched
  target ``extend`` verifies them (:mod:`repro.serving.speculative`).
  Seeded acceptance makes the output stream token-identical to a
  non-speculative run, greedy AND stochastic; rejected rows roll back on
  the paged cache via the null-page redirect + ``kv_pos`` reset, and the
  subsystem composes with prefix caching (COW barrier before every
  verify), preemption (lazy draft resync from host truth), and the
  jnp/pallas × single/EP dispatch axes unchanged.
"""
from __future__ import annotations

import enum
import math
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.kvcache import (
    PageAllocator, PageExhausted, contiguous_kv_bytes, init_cache,
    init_paged_cache, paged_kv_page_bytes, prefix_keys, supports_paging)
from repro.serving.bucketing import (
    pad_prompts, plan_admission, plan_chunks, supports_bucketing)
from repro.serving.faults import FaultConfig, FaultInjector, InjectedFault
from repro.serving.sampling import (
    GREEDY, SamplingParams, finite_rows, sample_tokens, sampling_arrays)


class RequestStatus(enum.Enum):
    """Request lifecycle states. QUEUED / PREFILLING / RUNNING are
    transient (a preempted request returns to QUEUED); the other four are
    terminal. ``Request.done`` is True exactly in a terminal state."""

    QUEUED = "queued"
    PREFILLING = "prefilling"     # chunked prefill in progress
    RUNNING = "running"           # decoding
    FINISHED = "finished"         # max_new_tokens or EOS
    CANCELLED = "cancelled"       # engine.cancel(uid)
    EXPIRED = "expired"           # deadline_s elapsed
    FAILED = "failed"             # quarantined (non-finite logits, splice)

    @property
    def terminal(self) -> bool:
        return self in (RequestStatus.FINISHED, RequestStatus.CANCELLED,
                        RequestStatus.EXPIRED, RequestStatus.FAILED)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # wall-clock budget from submission; checked at step boundaries, so
    # enforcement granularity is one engine step. None = no deadline.
    deadline_s: Optional[float] = None
    status: RequestStatus = RequestStatus.QUEUED
    error: str = ""               # why status == FAILED
    # --- telemetry (filled by the engine; perf_counter timestamps) ---
    t_submit: float = 0.0
    t_admit: float = 0.0          # FIRST admission (stable across preemption)
    t_first_token: float = 0.0
    t_done: float = 0.0
    # total prefill wall time this request rode in, ACCUMULATED (+=) so a
    # chunked prefill sums its chunks exactly once — never overwritten per
    # call, which would double-count shared calls or drop all but the last
    # chunk
    prefill_time: float = 0.0
    preemptions: int = 0          # times evicted and requeued
    requeue_wait_s: float = 0.0   # total preempt -> re-admit wall time
    admit_seq: int = -1           # engine-global admission order (LIFO victim)
    prefix_rows: int = 0          # prompt rows served from shared pages at
    #                               the LAST admission (0 = cold prefill)
    _t_preempt: float = 0.0       # pending preemption timestamp (internal)

    def __post_init__(self):
        # SamplingParams is the one user-facing generation-control surface:
        # its max_new / deadline_s, when set, override the Request fields
        # (which stay for telemetry and direct construction)
        if self.sampling.max_new is not None:
            self.max_new_tokens = self.sampling.max_new
        if self.deadline_s is None:
            self.deadline_s = self.sampling.deadline_s

    @property
    def queue_time(self) -> float:
        """Submission → first admission. NaN until admitted — a missing
        timestamp must not masquerade as an instant admission."""
        if self.t_submit == 0.0 or self.t_admit == 0.0:
            return float("nan")
        return self.t_admit - self.t_submit

    @property
    def ttft(self) -> float:
        """Time to first token, from submission; NaN if no token was ever
        produced (cancelled/expired while queued, failed admission)."""
        if self.t_submit == 0.0 or self.t_first_token == 0.0:
            return float("nan")
        return self.t_first_token - self.t_submit

    @property
    def tokens_per_s(self) -> float:
        """Decode throughput over the request's resident lifetime (first
        admission → terminal); NaN for zero-token or never-admitted
        requests rather than a fake 0.0."""
        if not self.generated or self.t_admit == 0.0 or self.t_done == 0.0:
            return float("nan")
        dt = self.t_done - self.t_admit
        return len(self.generated) / dt if dt > 0 else float("nan")


def _nanmean(values) -> float:
    """Mean over the non-NaN entries; 0.0 when none remain (stats of an
    idle engine stay zeros, not NaN-poisoned)."""
    vals = [v for v in values if not math.isnan(v)]
    return float(np.mean(vals)) if vals else 0.0


@dataclass
class ServingStats:
    requests: int
    total_new_tokens: int
    wall_time_s: float
    tokens_per_s: float            # aggregate decode throughput
    mean_ttft_s: float
    mean_queue_s: float
    mean_prefill_s: float
    prefill_calls: int
    prefill_compilations: int      # distinct compiled prefill shapes
    decode_steps: int
    decode_time_s: float = 0.0     # wall time inside decode dispatches
    decode_step_ms: float = 0.0    # mean per-step decode latency
    prefill_chunk_calls: int = 0   # chunked-prefill extend dispatches
    max_step_s: float = 0.0        # longest single engine step (stall proxy)
    # paged-KV occupancy (zeros under the contiguous layout)
    kv_pages_total: int = 0        # allocatable pages in the pool
    kv_pages_in_use: int = 0       # pages owned by resident requests NOW
    kv_pages_peak: int = 0         # high-water mark since reset_stats
    kv_page_util: float = 0.0      # kv_pages_peak / kv_pages_total
    kv_bytes_peak: int = 0         # pages_peak * per-page bytes (all layers)
    kv_bytes_contiguous: int = 0   # what the contiguous layout provisions
    # per-device accounting under a mesh: K/V arrays are split
    # kv_shard_degree ways (choose_kv_spec — kv heads or head_dim over tp),
    # so each device holds kv_bytes_peak_per_device of the pools, NOT the
    # replicated total. Both are 1x the global numbers single-device.
    kv_shard_degree: int = 1
    kv_bytes_peak_per_device: int = 0
    # lifecycle / overload accounting
    preemptions: int = 0           # eviction events since reset_stats
    mean_requeue_wait_s: float = 0.0   # mean preempt -> re-admit latency
    cancelled: int = 0             # terminal-status counts over `requests`
    expired: int = 0
    failed: int = 0
    # cross-request prefix cache (zeros when prefix_cache is off)
    prefix_hits: int = 0           # admissions spliced onto cached pages
    prefix_misses: int = 0         # admissions that cold-prefilled
    prefix_hit_rate: float = 0.0   # hits / (hits + misses)
    prefix_rows_reused: int = 0    # prompt rows served from shared pages
    kv_bytes_saved: int = 0        # KV bytes those rows did NOT re-store
    kv_pages_cached: int = 0       # resident unreferenced cache pages NOW
    mean_ttft_warm_s: float = 0.0  # mean TTFT of prefix-hit requests
    mean_ttft_cold_s: float = 0.0  # mean TTFT of prefix-miss requests
    prefix_evictions: int = 0      # prefix entries LRU-dropped
    cow_copies: int = 0            # copy-on-write page copies
    # speculative decoding (zeros when ServingConfig.speculative is None)
    spec_rounds: int = 0           # draft+verify rounds (1 target dispatch
    #                                each; spec_rounds == decode_steps)
    draft_tokens: int = 0          # drafted tokens submitted to the verifier
    draft_accepted: int = 0        # drafts the target accepted
    acceptance_rate: float = 0.0   # draft_accepted / draft_tokens
    spec_tokens_per_round: float = 0.0  # mean tokens a STREAM emits per
    #                                verify it rides in (>= 1): the
    #                                per-stream decode-step speedup over
    #                                one-token-per-dispatch decode
    draft_time_s: float = 0.0      # wall time inside draft-model dispatches


@dataclass
class ServingConfig:
    """Engine configuration (see the class docstring above for what each
    knob controls). ``ServingEngine(model, params, config=ServingConfig(...))``
    is the ONE documented construction path (docs/serving_api.md); the
    flat-kwarg form is deprecated and only kept as a warning back-compat
    shim. :meth:`validate` is the ONE site holding the paged/EP/pallas
    incompatibility rules, and :meth:`from_args` the ONE place CLI flags
    become a config — programmatic and CLI configs cannot drift."""
    batch_slots: int = 4
    max_len: int = 512
    moe_mode: str = "ragged"
    eos_id: Optional[int] = None
    bucket_prompts: Optional[bool] = None
    min_bucket: int = 8
    prefill_batch: Optional[int] = None
    attn_impl: Optional[str] = None        # None: keep model.cfg.attn_impl
    kv_layout: str = "contiguous"          # contiguous | paged
    kv_page_size: Optional[int] = None
    kv_pages: Optional[int] = None
    prefill_chunk: Optional[int] = None    # paged layout only
    # cross-request prefix caching (paged layout only): share chunk-aligned
    # prompt-prefix pages across requests with refcounts + copy-on-write;
    # prefix_cache_pages caps the resident unreferenced cache footprint
    # (None = bounded only by allocation pressure / LRU eviction)
    prefix_cache: bool = False
    prefix_cache_pages: Optional[int] = None
    parallel: Optional[object] = None      # ParallelConfig for EP serving
    mesh: Optional[object] = None
    # paged admission policy: "optimistic" admits against the rows a
    # request will actually occupy (prompt + generated + 1) and preempts
    # under pressure; "reserve" keeps worst-case (prompt + max_new) page
    # reservation — no preemption, lower pool utilization
    admission: str = "optimistic"
    # drop requests whose sampled logits go non-finite (status FAILED)
    # instead of crashing the batch
    logit_guard: bool = True
    # deterministic fault injection (repro.serving.faults.FaultConfig)
    faults: Optional[object] = None
    # compression plan (repro.core.plan.MergePlan) applied to the served
    # params at engine load time — the offline-computed artifact path
    merge_plan: Optional[object] = None
    # speculative decoding (repro.serving.speculative.SpecConfig): a
    # MergePlan-derived draft model proposes k tokens per round and ONE
    # batched target extend verifies them — lossless by the seeded-
    # acceptance rule (paged layout only; see docs/serving_lifecycle.md)
    speculative: Optional[object] = None

    def validate(self, model_cfg=None) -> None:
        """Canonical cross-feature compatibility rules. Pure-config rules
        always run; rules needing the (post-``attn_impl``-rebuild) model
        config run when ``model_cfg`` is given.

        The three serving axes — KV layout × attention backend × expert
        parallelism — compose freely; only genuinely-impossible combos are
        rejected here (malformed values, chunked prefill without paging,
        paging over non-attention mixers)."""
        if self.kv_layout not in ("contiguous", "paged"):
            raise ValueError(
                f"kv_layout must be 'contiguous' or 'paged', got "
                f"{self.kv_layout!r}")
        paged = self.kv_layout == "paged"
        if not paged and self.prefill_chunk:
            raise ValueError(
                "prefill_chunk > 0 requires kv_layout='paged' (chunked "
                "prefill writes the cache page-by-page)")
        if not paged and self.prefix_cache:
            raise ValueError(
                "prefix_cache=True requires kv_layout='paged' (prefix "
                "sharing maps physical pages into several page tables; a "
                "contiguous ring has no pages to share)")
        if self.prefix_cache_pages is not None:
            if not self.prefix_cache:
                raise ValueError(
                    "prefix_cache_pages is set but prefix_cache=False "
                    "(enable the cache or drop the cap)")
            if self.prefix_cache_pages < 0:
                raise ValueError(
                    f"prefix_cache_pages must be >= 0, got "
                    f"{self.prefix_cache_pages}")
        if self.admission not in ("optimistic", "reserve"):
            raise ValueError(
                f"admission must be 'optimistic' or 'reserve', got "
                f"{self.admission!r}")
        if self.faults is not None:
            if not isinstance(self.faults, FaultConfig):
                raise ValueError(
                    "faults must be a repro.serving.faults.FaultConfig, "
                    f"got {type(self.faults).__name__}")
            self.faults.validate()
        if self.speculative is not None:
            from repro.serving.speculative import SpecConfig

            if not isinstance(self.speculative, SpecConfig):
                raise ValueError(
                    "speculative must be a "
                    "repro.serving.speculative.SpecConfig, got "
                    f"{type(self.speculative).__name__}")
            self.speculative.validate()
            if not paged:
                raise ValueError(
                    "speculative decoding requires kv_layout='paged': the "
                    "verifier is the multi-token extend path and rollback "
                    "needs the null-page write redirect")
        if model_cfg is None:
            return
        if paged and not supports_paging(model_cfg):
            raise ValueError(
                f"{model_cfg.name}: kv_layout='paged' requires "
                "attention-family mixers only (MLA / recurrent state "
                "and enc-dec caches keep the contiguous layout)")

    # ------------------------------------------------------------- CLI
    @classmethod
    def add_cli_args(cls, ap):
        """Register every engine flag on an argparse parser. Launchers add
        their workload flags (prompts, sampling, request count) and then
        build the config with :meth:`from_args` — flag names, defaults,
        and the flag->field mapping live only here."""
        ap.add_argument("--slots", type=int, default=cls.batch_slots)
        ap.add_argument("--max-len", type=int, default=0,
                        help="engine context rows per slot (0 = let the "
                             "launcher derive it from its workload)")
        ap.add_argument("--moe-mode", default=cls.moe_mode)
        ap.add_argument("--attn-impl", default="jnp",
                        choices=("jnp", "pallas"),
                        help="decode/prefill attention backend: 'pallas' "
                             "runs the flash-decode + flash-attention "
                             "kernels (interpret mode on CPU)")
        ap.add_argument("--kv-layout", default="contiguous",
                        choices=("contiguous", "paged"),
                        help="'paged' serves from a shared page pool "
                             "(block-table allocator, on-demand growth, "
                             "release on retirement) instead of per-slot "
                             "max_len rings")
        ap.add_argument("--kv-page-size", type=int, default=0,
                        help="rows per KV page (default: cfg.kv_page_size)")
        ap.add_argument("--kv-pages", type=int, default=0,
                        help="physical pages in the pool (default: worst "
                             "case slots * max_len / page + null page)")
        ap.add_argument("--prefill-chunk", type=int, default=0,
                        help="chunked prefill: prompts longer than this "
                             "many tokens prefill chunk-by-chunk "
                             "interleaved with decode (paged layout only; "
                             "0 = off)")
        ap.add_argument("--prefix-cache", action="store_true",
                        help="cross-request prefix caching (paged layout "
                             "only): requests sharing a prompt prefix "
                             "splice the cached pages into their page "
                             "table and skip prefilling them; divergent "
                             "writes copy-on-write")
        ap.add_argument("--prefix-cache-pages", type=int, default=0,
                        help="cap on resident unreferenced prefix-cache "
                             "pages (0 = LRU eviction under allocation "
                             "pressure only)")
        ap.add_argument("--no-bucketing", action="store_true",
                        help="exact-length per-request prefill (recompiles "
                             "per distinct prompt length)")
        ap.add_argument("--ep", action="store_true",
                        help="expert-parallel serving: shard MoE expert "
                             "stacks over the 'model' mesh axis")
        ap.add_argument("--ep-degree", type=int, default=0,
                        help="EP mesh size (default: all visible devices)")
        ap.add_argument("--admission", default=cls.admission,
                        choices=("optimistic", "reserve"),
                        help="paged admission policy: 'optimistic' admits "
                             "against expected occupancy and preempts on "
                             "pool exhaustion (recompute on re-admission); "
                             "'reserve' budgets worst-case pages up front "
                             "and never preempts (see "
                             "docs/serving_lifecycle.md)")
        ap.add_argument("--spec-draft-plan", default="",
                        help="speculative decoding: saved MergePlan "
                             "directory (launch/compress.py compute) built "
                             "from the SAME base checkpoint; the engine "
                             "applies it at load time as the draft model "
                             "(paged layout only). Output is token-"
                             "identical to a non-speculative run.")
        ap.add_argument("--spec-k", type=int, default=4,
                        help="draft tokens per speculative round (one "
                             "batched target verify per round)")
        ap.add_argument("--chaos", action="store_true",
                        help="arm the deterministic fault injector "
                             "(repro.serving.faults): forced preemptions + "
                             "simulated pool exhaustion; greedy output "
                             "must stay token-identical to an undisturbed "
                             "run")
        ap.add_argument("--chaos-seed", type=int, default=0)
        ap.add_argument("--chaos-preempt-every", type=int, default=4,
                        help="force-preempt the newest resident every N "
                             "engine steps under --chaos (0 = off)")
        ap.add_argument("--chaos-exhaust-prob", type=float, default=0.1,
                        help="per-ensure probability that page growth "
                             "pretends the pool is dry under --chaos")
        return ap

    @classmethod
    def from_args(cls, args, **overrides) -> "ServingConfig":
        """Build a config from parsed :meth:`add_cli_args` flags.
        ``overrides`` win over flag values — launchers use this for
        derived fields (``max_len`` from the workload, a loaded
        ``merge_plan``). Mesh / ParallelConfig / FaultConfig construction
        happens here, so --ep and --chaos mean the same thing in every
        launcher."""
        parallel = mesh = None
        if getattr(args, "ep", False):
            from repro.launch.mesh import make_serving_mesh
            from repro.parallel import ParallelConfig

            mesh = make_serving_mesh(getattr(args, "ep_degree", 0) or None)
            parallel = ParallelConfig(fsdp_axis=None, weight_gather=False,
                                      ep=True, moe_mode=args.moe_mode)
        faults = None
        if getattr(args, "chaos", False):
            faults = FaultConfig(seed=args.chaos_seed,
                                 preempt_every=args.chaos_preempt_every,
                                 exhaust_prob=args.chaos_exhaust_prob)
        speculative = None
        if getattr(args, "spec_draft_plan", ""):
            from repro.serving.speculative import SpecConfig

            speculative = SpecConfig(draft_plan=args.spec_draft_plan,
                                     k=args.spec_k)
        fields = dict(
            batch_slots=args.slots,
            max_len=args.max_len or cls.max_len,
            moe_mode=args.moe_mode,
            attn_impl=args.attn_impl,
            bucket_prompts=False if args.no_bucketing else None,
            kv_layout=args.kv_layout,
            kv_page_size=args.kv_page_size or None,
            kv_pages=args.kv_pages or None,
            prefill_chunk=args.prefill_chunk or None,
            prefix_cache=args.prefix_cache,
            prefix_cache_pages=args.prefix_cache_pages or None,
            admission=args.admission,
            faults=faults, speculative=speculative,
            parallel=parallel, mesh=mesh)
        fields.update(overrides)
        return cls(**fields)


def splice_ring(cache, slots: List[int], cacheN, lens) -> dict:
    """Copy rows ``0..len(slots)-1`` of a prefill cache (batch B', ring
    layout) into a contiguous engine cache at ``slots``, returning the new
    cache pytree. Batch dim is 0 for "pos"/"prefix" leaves and 1 for
    stacked block leaves (leading n_blocks dim). ``kv_pos`` entries at
    padded positions (>= the row's true length) are reset to -1 so decode
    masks never attend to padding. Shared by the engine's contiguous
    admission splice and the speculative draft cache's resync."""
    n = len(slots)
    slot_idx = np.asarray(slots, np.int32)
    lens = np.asarray(lens, np.int32)

    def visit(path, big, small):
        top = path[0].key
        leaf = getattr(path[-1], "key", None)
        if top == "pos":
            return big.at[slot_idx].set(jnp.asarray(lens))
        if top == "blocks":
            sel = small[:, :n]
            if leaf == "kv_pos":
                sel = jnp.where(sel >= lens[None, :, None], -1, sel)
            return big.at[:, slot_idx].set(sel)
        sel = small[:n]
        if leaf == "kv_pos":
            sel = jnp.where(sel >= lens[:, None], -1, sel)
        return big.at[slot_idx].set(sel)

    return jax.tree_util.tree_map_with_path(visit, cache, cacheN)


class ServingEngine:
    def __init__(self, model, params, *,
                 config: Optional[ServingConfig] = None, **kwargs):
        if config is None:
            # deprecated back-compat shim; the stable constructor is
            # config= (docs/serving_api.md)
            warnings.warn(
                "flat-kwarg ServingEngine(model, params, batch_slots=..., "
                "...) is deprecated; pass "
                "config=ServingConfig(batch_slots=..., ...) instead",
                DeprecationWarning, stacklevel=2)
            config = ServingConfig(**kwargs)
        elif kwargs:
            raise ValueError(
                f"pass config= or individual engine kwargs, not both "
                f"(got config and {sorted(kwargs)})")
        self.config = config
        attn_impl = config.attn_impl
        if attn_impl is not None and attn_impl != model.cfg.attn_impl:
            # build_model closes over cfg, so a backend switch needs a
            # rebuild (cheap: closures only, no params)
            import dataclasses

            from repro.models import build_model

            model = build_model(
                dataclasses.replace(model.cfg, attn_impl=attn_impl))
        config.validate(model.cfg)
        # speculative decoding derives its draft from the BASE checkpoint:
        # capture the raw params before any target plan / EP padding /
        # sharding touches them (the draft plan was computed against them)
        base_params = params if config.speculative is not None else None
        if config.merge_plan is not None:
            # serve a compression plan computed offline: apply it to the
            # params before any EP padding/sharding sees them
            from repro.core.plan import apply_plan

            params = apply_plan(params, config.merge_plan)
        max_len = config.max_len
        moe_mode = config.moe_mode
        bucket_prompts = config.bucket_prompts
        kv_layout = config.kv_layout
        prefill_chunk = config.prefill_chunk
        batch_slots = config.batch_slots
        kv_page_size = config.kv_page_size
        kv_pages = config.kv_pages
        parallel, mesh = config.parallel, config.mesh
        self.model = model
        self.cfg = model.cfg
        self.attn_impl = self.cfg.attn_impl
        self.slots = batch_slots
        if self.attn_impl == "pallas" and max_len > 128:
            # flash-decode streams the cache window in 128-row KV tiles;
            # round the window up so the tile size never degenerates to
            # gcd(max_len, 128) slivers on TPU (windows <= 128 run as one
            # tile of any size). Requests simply get a little extra room.
            max_len += (-max_len) % 128

        self.paged = kv_layout == "paged"
        # cfg.prefill_chunk only takes effect under the paged layout; an
        # EXPLICIT prefill_chunk argument with contiguous is an error
        # (rejected in ServingConfig.validate)
        self.prefill_chunk = (prefill_chunk if prefill_chunk is not None
                              else model.cfg.prefill_chunk) if self.paged \
            else 0
        if self.paged:
            self.page_size = min(kv_page_size or model.cfg.kv_page_size,
                                 max_len)
            max_len += (-max_len) % self.page_size
        self.max_len = max_len
        self.moe_mode = moe_mode
        self.eos_id = config.eos_id
        self.min_bucket = config.min_bucket
        self.prefill_batch = config.prefill_batch or batch_slots
        if bucket_prompts is None:
            bucket_prompts = supports_bucketing(self.cfg, max_len)
        elif bucket_prompts and not supports_bucketing(self.cfg, max_len):
            raise ValueError(
                "bucket_prompts=True but right-padded prefill is not exact "
                "for this architecture (recurrent mixer, short sliding "
                "window, or enc-dec/VLM inputs)")
        self.bucket_prompts = bucket_prompts

        if self.paged:
            self.pages_per_slot = self.max_len // self.page_size
            self.num_pages = kv_pages or (batch_slots * self.pages_per_slot
                                          + 1)

        self.pc = parallel
        self.mesh = None
        self._cache_sh = None          # engine cache (paged pools OR rings)
        self._prefill_cache_sh = None  # transient prefill (ring) cache
        self._kv_shards = 1
        self._extend = None
        self._verify = None            # speculative verifier (paged only)
        if parallel is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            from repro.launch.mesh import make_serving_mesh
            from repro.models.kvcache import cache_specs, paged_cache_specs
            from repro.parallel.sharding import (
                cache_pspecs_sized, kv_shard_degree, pad_expert_slots,
                param_pspecs)

            if mesh is None:
                mesh = make_serving_mesh()
            self.mesh = mesh
            tp_size = (int(mesh.shape[parallel.tp_axis])
                       if parallel.tp_axis in mesh.shape else 1)
            self._kv_shards = kv_shard_degree(self.cfg, tp_size)
            if (parallel.ep and self.cfg.moe is not None and tp_size > 1
                    and moe_mode in ("ragged", "pallas")):
                # merged models may have a slot count that does not divide
                # the EP degree; zero slots are never routed to. Capacity
                # mode must NOT be padded: it derives per-expert capacity
                # from the slot count (dead slots would shrink it), and its
                # GSPMD einsum path handles uneven expert sharding itself.
                params = pad_expert_slots(params, tp_size)
            is_spec = lambda s: isinstance(s, PartitionSpec)  # noqa: E731
            ns = lambda s: NamedSharding(mesh, s)  # noqa: E731
            param_sh = jax.tree.map(ns, param_pspecs(params, parallel),
                                    is_leaf=is_spec)
            params = jax.device_put(params, param_sh)
            repl = ns(PartitionSpec())
            # the transient prefill cache is ALWAYS the contiguous ring
            # layout — paged mode splices it into the pools host-side —
            # and NamedShardings are shape-polymorphic, so one sharding
            # tree covers every bucket length
            ring_struct = cache_specs(self.cfg, batch_slots, max_len,
                                      jnp.dtype(self.cfg.dtype))
            self._prefill_cache_sh = jax.tree.map(
                ns, cache_pspecs_sized(self.cfg, ring_struct, parallel,
                                       tp_size),
                is_leaf=is_spec)
            if self.paged:
                paged_struct = paged_cache_specs(
                    self.cfg, batch_slots, self.max_len,
                    num_pages=self.num_pages, page_size=self.page_size,
                    dtype=jnp.dtype(self.cfg.dtype))
                self._cache_sh = jax.tree.map(
                    ns, cache_pspecs_sized(self.cfg, paged_struct, parallel,
                                           tp_size),
                    is_leaf=is_spec)
                self._extend = jax.jit(
                    self._extend_fn,
                    in_shardings=(param_sh, repl, self._cache_sh, repl),
                    out_shardings=(repl, self._cache_sh))
                self._verify = jax.jit(
                    self._verify_fn,
                    in_shardings=(param_sh, repl, self._cache_sh, repl),
                    out_shardings=(repl, self._cache_sh))
            else:
                self._cache_sh = self._prefill_cache_sh
            self._decode = jax.jit(
                self._decode_fn,
                in_shardings=(param_sh, repl, self._cache_sh),
                out_shardings=(repl, self._cache_sh))
            self._prefill = jax.jit(
                self._prefill_fn,
                in_shardings=(param_sh, repl, repl),
                out_shardings=(repl, self._prefill_cache_sh))
        else:
            self._decode = jax.jit(self._decode_fn)
            self._prefill = jax.jit(self._prefill_fn)
        self.params = params

        self.prefix_cache = bool(config.prefix_cache)  # paged-only (validate)
        if self.paged:
            self.allocator = PageAllocator(
                self.num_pages, self.page_size,
                prefix_cache=self.prefix_cache,
                prefix_cache_pages=config.prefix_cache_pages)
            self.cache = init_paged_cache(
                self.cfg, batch_slots, self.max_len,
                num_pages=self.num_pages, page_size=self.page_size,
                dtype=jnp.dtype(self.cfg.dtype))
            if self._extend is None:
                self._extend = jax.jit(self._extend_fn)
            if self._verify is None:
                self._verify = jax.jit(self._verify_fn)
            self._table_dirty = False
            # one compiled extend width serves chunked prefill AND warm
            # suffix prefill; without explicit chunking, warm suffixes
            # stream at page granularity
            self._chunk_width = self.prefill_chunk or self.page_size
        else:
            self.allocator = None
            self.cache = init_cache(self.cfg, batch_slots, max_len,
                                    jnp.dtype(self.cfg.dtype))
            self._chunk_width = 0
        # one layout-resolved splice path for every admission site
        self._splice_fn = self._splice_paged if self.paged else self._splice
        self._place_cache()
        self.active: Dict[int, Request] = {}   # slot -> request
        # slot -> {"req", "tokens": full resume prompt, "chunks":
        #          plan_chunks spans, "next": span index}
        self.prefilling: Dict[int, dict] = {}
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.last_token = np.zeros((batch_slots, 1), np.int32)
        self.slot_live = np.zeros(batch_slots, bool)

        # lifecycle: admission policy, fault injection, cancellation
        self.admission = config.admission
        self.logit_guard = config.logit_guard
        self.faults = (FaultInjector(config.faults)
                       if config.faults is not None else None)
        self._cancel_uids: set = set()
        self._admit_counter = 0        # monotonic; LIFO preemption victims
        self._next_uid = 0             # auto uids for generate()
        self.engine_steps = 0          # every step() call; fault clock

        # telemetry
        self.prefill_calls = 0
        self.prefill_chunk_calls = 0
        self.prefill_shapes: set = set()
        self.decode_steps = 0
        self._run_time = 0.0
        self._decode_time = 0.0
        self._max_step_s = 0.0
        self._kv_pages_peak = 0
        self._prefill_cache_base = 0
        self.preemption_count = 0
        self._requeue_waits: List[float] = []
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_rows_reused = 0
        # allocator counters are monotonic; stats() reports deltas since
        # the last reset_stats via these baselines
        self._evict_base = 0
        self._cow_base = 0

        self.spec = None
        if config.speculative is not None:
            from repro.serving.speculative import SpecState

            self.spec = SpecState(self, base_params, config.speculative)

    def _prefill_fn(self, params, tokens, last_pos):
        # paged mode splices the transient prefill cache into the page pool
        # row-by-row, so it only needs to cover the bucket, not max_len
        cml = tokens.shape[1] if self.paged else self.max_len
        return self.model.prefill(params, tokens=tokens, last_pos=last_pos,
                                  moe_mode=self.moe_mode,
                                  cache_max_len=cml, pc=self.pc)

    def _decode_fn(self, params, tokens, cache):
        return self.model.decode_step(params, tokens=tokens, cache=cache,
                                      moe_mode=self.moe_mode, pc=self.pc)

    def _extend_fn(self, params, tokens, cache, valid):
        return self.model.extend(params, tokens=tokens, cache=cache,
                                 valid=valid, moe_mode=self.moe_mode,
                                 pc=self.pc)

    def _verify_fn(self, params, tokens, cache, valid):
        # extend with logits at EVERY row — the speculative verifier: one
        # dispatch scores a whole draft run (C = k + 1 rows per slot)
        return self.model.extend(params, tokens=tokens, cache=cache,
                                 valid=valid, moe_mode=self.moe_mode,
                                 pc=self.pc, all_logits=True)

    def _call(self, fn, *args):
        """Dispatch a jitted model call, under the mesh context in parallel
        mode (apply_layer reads the context mesh for EP/ZeRO-3 layouts)."""
        if self.mesh is None:
            return fn(*args)
        with self.mesh:
            return fn(*args)

    # ------------------------------------------------------------ admission
    def submit(self, req: Request):
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds engine "
                f"max_len ({self.max_len})")
        if self.paged:
            # fail fast on a request that can NEVER fit: even with every
            # other resident evicted, its worst case exceeds the pool.
            # Admitted requests are therefore always completable, which is
            # what lets preemption guarantee progress (zero PageExhausted
            # escapes the engine).
            worst = self.allocator.pages_for(self._worst_rows(req))
            if worst > self.allocator.num_pages - 1:
                raise RuntimeError(
                    f"kv_pages pool too small: request {req.uid} needs "
                    f"{worst} page(s) worst-case (prompt "
                    f"{len(req.prompt)} + max_new {req.max_new_tokens}) "
                    f"but the pool holds {self.allocator.num_pages - 1} "
                    "(raise kv_pages)")
        req.status = RequestStatus.QUEUED
        req.t_submit = time.perf_counter()
        # keep generate()'s auto uids clear of caller-chosen ones
        self._next_uid = max(self._next_uid, req.uid + 1)
        self.queue.append(req)

    def cancel(self, uid: int) -> bool:
        """Request cancellation of ``uid``. Applied at the next step
        boundary: the request reaches terminal status CANCELLED, its slot
        and pages are released, and any already-generated tokens are kept.
        Returns False if ``uid`` is unknown or already terminal."""
        resident = [r.uid for r in self.queue]
        resident += [r.uid for r in self.active.values()]
        resident += [st["req"].uid for st in self.prefilling.values()]
        if uid not in resident:
            return False
        self._cancel_uids.add(uid)
        return True

    def generate(self, prompt,
                 params: Optional[SamplingParams] = None) -> Request:
        """One-call convenience over :meth:`submit` / :meth:`step`: serve
        ``prompt`` to completion and return its terminal :class:`Request`
        (tokens in ``.generated``, outcome in ``.status``). ``params``
        carries ALL generation controls (temperature / top_p / seed /
        max_new / deadline_s); default is greedy with the engine default
        budget. Any concurrently submitted requests keep being served —
        this drives the shared engine loop, it does not lock it."""
        req = Request(uid=self._next_uid,
                      prompt=np.asarray(prompt, np.int32),
                      sampling=params if params is not None else GREEDY)
        self._next_uid += 1
        self.submit(req)
        steps = 0
        while not req.done and steps < 10_000:
            self.step()
            steps += 1
        return req

    def _splice(self, slots: List[int], cacheN, lens: np.ndarray):
        """Copy rows ``0..len(slots)-1`` of a prefill cache (batch B') into
        the engine cache at ``slots`` (see :func:`splice_ring`)."""
        self.cache = splice_ring(self.cache, slots, cacheN, lens)
        self._place_cache()

    def _place_cache(self):
        """Re-place the cache onto the engine cache shardings after a
        host-side (eager) mutation — splice, page-table sync, page
        release, slot reset — so the next jitted dispatch matches its
        in_shardings with zero resharding. No-op single-device."""
        if self._cache_sh is not None:
            self.cache = jax.device_put(self.cache, self._cache_sh)

    # ------------------------------------------------------- paged helpers
    def _note_pages(self):
        self._kv_pages_peak = max(self._kv_pages_peak,
                                  self.allocator.pages_in_use)

    def _sync_page_table(self):
        """Push the host allocator's state to the device page table (only
        when an alloc/release actually changed it)."""
        if not self._table_dirty:
            return
        t = np.stack([self.allocator.table_row(s, self.pages_per_slot)
                      for s in range(self.slots)])
        self.cache["page_table"] = jnp.asarray(t)
        self._table_dirty = False
        self._place_cache()

    def _reset_kv_rows(self, pages: List[int]):
        """Neutralise the kv_pos rows of freed pages: stale entries in a
        recycled page would masquerade as filled positions for its next
        owner (the leftover k/v bytes are then masked like any unfilled
        slot)."""
        if pages:
            self.cache["kv_pos"] = self.cache["kv_pos"].at[
                jnp.asarray(np.asarray(pages, np.int32))].set(-1)
            self._place_cache()

    def _drain_evicted(self):
        """Collect pages the prefix cache evicted to the free list during
        the last allocator call and reset their stale kv_pos rows. Pages
        the SAME allocator call already handed back out (evicted straight
        into an ensure/cow allocation) are skipped — they are live again
        and their kv_pos is owned by the allocation site, which either
        reset it as a fresh page or overwrote it with the COW copy."""
        if self.prefix_cache:
            stale = [p for p in self.allocator.drain_evicted()
                     if self.allocator.refs(p) == 0]
            self._reset_kv_rows(stale)

    def _ensure_pages(self, slot: int, n_rows: int):
        before = len(self.allocator.owned(slot))
        if self.allocator.ensure(slot, n_rows):
            self._table_dirty = True
            self._note_pages()
            if self.prefix_cache:
                # a freshly allocated page has no valid rows by definition;
                # with eviction in play it may come back dirty (evicted
                # cache pages keep their kv_pos until recycled), so clean
                # it here, at the one place pages enter a slot's table
                self._reset_kv_rows(list(self.allocator.owned(slot)[before:]))
        self._drain_evicted()

    def _release_pages(self, slot: int):
        had_pages = bool(self.allocator.owned(slot))
        # release DECREFS: only pages no other slot maps and the prefix
        # index no longer caches come back (shared pages must survive
        # their co-owners; cached pages stay resident for future hits)
        self._reset_kv_rows(self.allocator.release(slot))
        self._drain_evicted()
        if had_pages:
            self._table_dirty = True

    def _worst_rows(self, req: Request) -> int:
        return len(req.prompt) + req.max_new_tokens

    def _resume_prompt(self, req: Request) -> np.ndarray:
        """The tokens to prefill at (re-)admission: the original prompt
        plus every already-generated token, so a preempted request's KV is
        recomputed exactly and its next sample (counter = len(generated))
        continues the stream token-identically."""
        if not req.generated:
            return req.prompt
        return np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.generated, np.int32)])

    def _admission_rows(self, req: Request) -> int:
        """Rows a request must be able to occupy to be admitted. The
        optimistic policy budgets what admission actually writes (the
        resume prompt) plus one decode row; "reserve" budgets the worst
        case, so growth can never exhaust the pool (no preemption)."""
        if self.admission == "reserve":
            return self._worst_rows(req)
        return len(req.prompt) + len(req.generated) + 1

    def _fits_pages(self, n_rows_list) -> bool:
        return self._fits_page_budget(
            sum(self.allocator.pages_for(r) for r in n_rows_list))

    def _fits_page_budget(self, need_pages: int) -> bool:
        """Can the unreserved pool budget this many pages right now?
        Raises instead of deadlocking when nothing resident could ever
        free a page (the submit-time worst-case check already rejected
        requests the EMPTY pool can't hold, so this only triggers on
        fragmentation across policy edge cases)."""
        if need_pages <= self.allocator.pages_available:
            return True
        if not (self.slot_live.any() or self.prefilling):
            raise RuntimeError(
                f"kv_pages pool too small: admission needs a budget of "
                f"{need_pages} page(s), only "
                f"{self.allocator.pages_available} of "
                f"{self.allocator.num_pages - 1} are unreserved and no "
                "resident request will release any (raise kv_pages)")
        return False

    def _clamp_to_pool(self, reqs: List[Request], n: int) -> int:
        """Largest FCFS prefix of ``reqs[:n]`` whose admission page
        budgets the unreserved pool can hold."""
        budget = self.allocator.pages_available
        fit = 0
        for r in reqs[:n]:
            need = self.allocator.pages_for(self._admission_rows(r))
            if need > budget:
                break
            budget -= need
            fit += 1
        if fit == 0:
            self._fits_pages([self._admission_rows(reqs[0])])  # may raise
        return fit

    # ---------------------------------------------------------- preemption
    def _evict(self, slot: int):
        """Remove whatever occupies ``slot`` (decode or prefill tenant)
        and release its pages. Caller decides the request's fate."""
        self.active.pop(slot, None)
        self.prefilling.pop(slot, None)
        self.slot_live[slot] = False
        if self.paged:
            self._release_pages(slot)

    def _preempt_victim(self, exclude=()) -> Optional[int]:
        """LIFO victim choice: the latest-admitted resident slot (decode
        or prefilling), so the oldest work — closest to finishing, most
        KV already paid for — is protected. vLLM's recompute policy."""
        cands = [(req.admit_seq, s) for s, req in self.active.items()
                 if s not in exclude]
        cands += [(st["req"].admit_seq, s)
                  for s, st in self.prefilling.items() if s not in exclude]
        return max(cands)[1] if cands else None

    def _preempt(self, slot: int):
        """Evict ``slot`` and requeue its request at the FRONT of the
        queue with generated tokens kept; re-admission recomputes the KV
        via :meth:`_resume_prompt`. Token-identical under the sampling
        determinism contract (token i <- fold_in(seed, i))."""
        req = (self.prefilling[slot]["req"] if slot in self.prefilling
               else self.active[slot])
        self._evict(slot)
        req.status = RequestStatus.QUEUED
        req.preemptions += 1
        req._t_preempt = time.perf_counter()
        self.preemption_count += 1
        # FRONT of the queue: preempted work outranks never-admitted work
        # (FCFS by original arrival — victims are chosen newest-first, so
        # multiple insertions in one step restore arrival order)
        self.queue.insert(0, req)

    def _ensure_resident(self, slot: int, n_rows: int):
        """``_ensure_pages`` with overload handling: on pool exhaustion
        (real or injected) preempt the latest-admitted OTHER resident and
        retry. The submit-time worst-case check guarantees this loop
        terminates with the growth satisfied once enough victims are
        evicted — PageExhausted never escapes the engine."""
        if not self.paged:
            return
        while True:
            try:
                if (self.faults is not None
                        and self.allocator.pages_for(n_rows)
                        > len(self.allocator.owned(slot))
                        and self.faults.exhaust_now()):
                    raise PageExhausted(
                        f"injected pool exhaustion growing slot {slot}")
                self._ensure_pages(slot, n_rows)
                return
            except PageExhausted:
                victim = self._preempt_victim(exclude=(slot,))
                if victim is None:
                    raise
                self._preempt(victim)

    def _mark_admitted(self, req: Request, t: float, prefix_rows: int = 0):
        """Admission bookkeeping shared by every admission site: first
        admission fixes ``t_admit``; re-admissions account requeue
        latency; ``admit_seq`` orders preemption victims; prefix-cache
        hit/miss telemetry counts each ACTUAL admission (probes that
        didn't admit don't skew the hit rate)."""
        if req.t_admit == 0.0:
            req.t_admit = t
        if req._t_preempt:
            wait = max(0.0, t - req._t_preempt)
            req.requeue_wait_s += wait
            self._requeue_waits.append(wait)
            req._t_preempt = 0.0
        req.admit_seq = self._admit_counter
        self._admit_counter += 1
        if self.prefix_cache:
            req.prefix_rows = prefix_rows
            if prefix_rows:
                self.prefix_hits += 1
                self.prefix_rows_reused += prefix_rows
            else:
                self.prefix_misses += 1

    # ------------------------------------------------- prefix cache (paged)
    def _match_prefix(self, req: Request):
        """The longest cached prefix of the request's resume prompt, or
        None (miss, or prefix caching off). Pure probe — LRU order is
        only refreshed when the match is actually spliced."""
        if not self.prefix_cache:
            return None
        cands = prefix_keys(self._resume_prompt(req), self.page_size)
        if not cands:
            return None
        return self.allocator.match_prefix(cands, touch=False)

    def _register_prefix(self, slot: int, tokens: np.ndarray):
        """Publish ``slot``'s prompt pages (rows 0..len(tokens)-1 just
        written by prefill) to the cross-request cache. Runs at every
        cold-prefill completion AND at warm completion — a warm request
        extends the index with its own longer prefixes."""
        if not self.prefix_cache:
            return
        self.allocator.register_prefix(slot,
                                       prefix_keys(tokens, self.page_size))
        self._drain_evicted()  # registering may trim past the page cap

    def _admit_warm(self, slot: int, entry, retired: List[Request]) -> bool:
        """Admit the queue head onto a cached prefix: splice the shared
        pages into its page table (incref), jump its cache ``pos`` past
        the cached rows — their ``kv_pos`` already holds the absolute
        positions — and route only the SUFFIX through the chunked-extend
        prefill path. Returns False when the pool cannot budget the
        admission yet (caller waits for retirements)."""
        req = self.queue[0]
        resume = self._resume_prompt(req)
        if self.admission == "reserve":
            # worst-case pages, PLUS one per refs-1 entry page: splicing
            # bumps those to refs 2, un-backing the publisher slot's
            # reservation (see PageAllocator._exclusive) — the consumer
            # fronts the replacement so the no-deadlock guarantee holds
            unbacks = sum(1 for p in entry.pages
                          if self.allocator.refs(p) == 1)
            need = self.allocator.pages_for(self._worst_rows(req)) + unbacks
        else:
            # pages beyond the shared ones, plus one for the boundary-page
            # COW the first divergent write triggers on a mid-page match
            need = (self.allocator.pages_for(len(resume) + 1)
                    - len(entry.pages)
                    + (1 if entry.n_rows % self.page_size else 0))
        if not self._fits_page_budget(max(need, 0)):
            return False
        self.queue.pop(0)
        self.allocator.splice_prefix(slot, entry)
        if self.admission == "reserve":
            self.allocator.reserve(slot, self._worst_rows(req))
        self._table_dirty = True
        self._note_pages()
        self._mark_admitted(req, time.perf_counter(),
                            prefix_rows=entry.n_rows)
        req.status = RequestStatus.PREFILLING
        self.cache["pos"] = self.cache["pos"].at[slot].set(entry.n_rows)
        self._place_cache()
        # absolute spans over the suffix only; the shared extend machinery
        # (_advance_prefills) prefills them at the engine's one chunk width
        spans = [(s + entry.n_rows, e + entry.n_rows)
                 for s, e in plan_chunks(len(resume) - entry.n_rows,
                                         self._chunk_width)]
        self.prefilling[slot] = {"req": req, "tokens": resume,
                                 "chunks": spans, "next": 0}
        return True

    def _cow_for_write(self, slot: int, start_row: int, end_row: int):
        """Copy-on-write every SHARED page the coming write to rows
        ``[start_row, end_row)`` would touch, so a writer never mutates a
        page another request (or the prefix index) maps. Allocation
        pressure preempts other residents, like any growth."""
        if not self.prefix_cache:
            return
        page = self.page_size
        owned = self.allocator.owned(slot)
        for li in range(start_row // page, (end_row - 1) // page + 1):
            if li >= len(owned):
                continue  # not allocated yet: fresh page, never shared
            if not self.allocator.page_shared(owned[li]):
                continue
            pair = None
            while True:
                try:
                    pair = self.allocator.cow(slot, li)
                    break
                except PageExhausted:
                    # the failed claim's eviction sweep stands — it may
                    # have dropped the very entry caching this page; a
                    # refs-1 uncached page is exclusive again and can be
                    # written in place, no copy (and no page) needed
                    if not self.allocator.page_shared(owned[li]):
                        break
                    victim = self._preempt_victim(exclude=(slot,))
                    if victim is None:
                        raise
                    self._preempt(victim)
            if pair is None:
                self._drain_evicted()
                continue
            # copy old -> new BEFORE draining evictions: the decref may
            # have freed the old page (its last cache entry was evicted
            # under the same allocation pressure), and a drain-first order
            # would wipe its kv_pos row before the copy reads it
            self._apply_cow([pair])
            self._drain_evicted()
            owned = self.allocator.owned(slot)

    def _apply_cow(self, pairs):
        """Device-side half of COW: duplicate each old page's pool rows
        (every attention layer) and its shared kv_pos row into the
        replacement page; the page-table swap already happened host-side
        in the allocator."""
        old = jnp.asarray(np.asarray([p[0] for p in pairs], np.int32))
        new = jnp.asarray(np.asarray([p[1] for p in pairs], np.int32))
        kvp = self.cache["kv_pos"]
        self.cache["kv_pos"] = kvp.at[new].set(kvp[old])
        self.cache["prefix"] = tuple(
            {k: pool[k].at[new].set(pool[k][old]) for k in ("k", "v")}
            for pool in self.cache["prefix"])
        self.cache["blocks"] = tuple(
            {k: pool[k].at[:, new].set(pool[k][:, old]) for k in ("k", "v")}
            for pool in self.cache["blocks"])
        self._table_dirty = True
        self._note_pages()
        self._place_cache()

    def _splice_paged(self, slots: List[int], cacheN, lens: np.ndarray):
        """Scatter a CONTIGUOUS prefill cache (ring layout, batch B') into
        the page pools at ``slots``. Every ring row holding a real absolute
        position ``0 <= p < len`` lands at its slot's physical page row;
        padded rows (``kv_pos >= len``) and unfilled rows are dropped. A
        local layer's ring may have retained fewer than ``len`` positions —
        exactly the ones the sliding-window mask excludes, so the shared
        ``kv_pos`` can still mark the full prefix filled."""
        n = len(slots)
        page, P = self.page_size, self.pages_per_slot
        lens = np.asarray(lens, np.int64)
        for j, s in enumerate(slots):
            self._ensure_pages(s, int(lens[j]))
        tables = np.stack([self.allocator.table_row(s, P)
                           for s in slots]).astype(np.int64)  # (n, P)

        # shared kv_pos: positions 0..len-1 of every admitted slot
        idx = np.concatenate([
            tables[j, np.arange(lens[j]) // page] * page
            + np.arange(lens[j]) % page for j in range(n)])
        vals = np.concatenate([np.arange(lens[j], dtype=np.int32)
                               for j in range(n)])
        kvp = self.cache["kv_pos"]
        self.cache["kv_pos"] = kvp.reshape(-1).at[jnp.asarray(idx)].set(
            jnp.asarray(vals)).reshape(kvp.shape)

        def dest(ring_kvp):
            """Flat pool rows for one layer's ring kv_pos (n, W) plus the
            selector of ring entries that hold real positions."""
            valid = (ring_kvp >= 0) & (ring_kvp < lens[:, None])
            p = np.clip(ring_kvp, 0, None).astype(np.int64)
            phys = np.take_along_axis(
                tables, np.minimum(p // page, P - 1), axis=1)
            flat = phys * page + p % page
            sel = np.nonzero(valid.reshape(-1))[0]
            return jnp.asarray(flat.reshape(-1)[sel]), sel

        def scatter(pool, ring, flat, sel, stacked: bool):
            shp = pool.shape
            if stacked:  # pool (nb, N, page, K, hd); ring (nb, B', W, K, hd)
                nb = shp[0]
                src = ring[:, :n].reshape((nb, -1) + ring.shape[3:])[:, sel]
                return pool.reshape((nb, shp[1] * shp[2]) + shp[3:]).at[
                    :, flat].set(src).reshape(shp)
            src = ring[:n].reshape((-1,) + ring.shape[2:])[sel]
            return pool.reshape((shp[0] * shp[1],) + shp[2:]).at[
                flat].set(src).reshape(shp)

        prefix = []
        for pool_l, ring_l in zip(self.cache["prefix"], cacheN["prefix"]):
            flat, sel = dest(np.asarray(ring_l["kv_pos"])[:n])
            prefix.append({k: scatter(pool_l[k], ring_l[k], flat, sel, False)
                           for k in ("k", "v")})
        blocks = []
        for pool_l, ring_l in zip(self.cache["blocks"], cacheN["blocks"]):
            # ring kv_pos is identical across the stacked blocks (it only
            # depends on positions and the ring width): index via block 0
            flat, sel = dest(np.asarray(ring_l["kv_pos"][0])[:n])
            blocks.append({k: scatter(pool_l[k], ring_l[k], flat, sel, True)
                           for k in ("k", "v")})
        self.cache["prefix"] = tuple(prefix)
        self.cache["blocks"] = tuple(blocks)
        self.cache["pos"] = self.cache["pos"].at[
            jnp.asarray(np.asarray(slots, np.int32))].set(
            jnp.asarray(lens.astype(np.int32)))
        self._sync_page_table()
        self._place_cache()

    def _record_prefill(self, shape):
        self.prefill_calls += 1
        self.prefill_shapes.add(tuple(shape))

    def _occupy(self, req: Request, slot: int, tok: int, now: float,
                retired: List[Request]):
        """A prefilled request joins the decode batch with its first newly
        sampled token (or immediately retires on it)."""
        req.status = RequestStatus.RUNNING
        req.generated.append(tok)
        if req.t_first_token == 0.0:
            req.t_first_token = now
        self.last_token[slot, 0] = tok
        self.active[slot] = req
        self.slot_live[slot] = True
        self._maybe_retire(slot, tok, retired)

    def _assign(self, reqs: List[Request], slots: List[int],
                first_tokens: np.ndarray, t_admit: float, prefill_dt: float,
                retired: List[Request]):
        """Book-keeping shared by both admission paths: record telemetry,
        store the first sampled token, occupy (or immediately retire)."""
        now = time.perf_counter()
        for req, slot, tok in zip(reqs, slots, first_tokens):
            self._mark_admitted(req, t_admit)
            req.prefill_time += prefill_dt
            self._occupy(req, slot, int(tok), now, retired)

    def _splice_admitted(self, reqs: List[Request], slots: List[int],
                         cacheN, lens, retired: List[Request]) -> bool:
        """Run the layout splice for an admitted batch, absorbing an
        injected splice failure: the whole batch reaches terminal FAILED
        (pages released, slots still free) and serving continues. Real
        splice exceptions still propagate — they are engine bugs, not a
        condition to degrade around."""
        if self.faults is not None:
            bad = self.faults.splice_fail_now([r.uid for r in reqs])
            if bad >= 0:
                now = time.perf_counter()
                for req, slot in zip(reqs, slots):
                    if self.paged:
                        self._release_pages(slot)
                    req.error = (f"admission splice failed (injected at "
                                 f"uid {bad})")
                    self._terminate(req, None, RequestStatus.FAILED,
                                    retired, now)
                return False
        self._splice_fn(slots, cacheN, lens)
        return True

    def _is_chunked(self, req: Request) -> bool:
        return bool(self.prefill_chunk) and \
            len(self._resume_prompt(req)) > self.prefill_chunk

    def _admit(self, retired: List[Request]):
        while self.queue:
            free = [s for s in range(self.slots)
                    if not self.slot_live[s] and s not in self.prefilling]
            if not free:
                return
            entry = self._match_prefix(self.queue[0])
            if entry is not None:
                # warm prefix: skip prefill for the cached rows entirely
                if not self._admit_warm(free[0], entry, retired):
                    return  # wait: retirements release budgeted pages
                continue
            if self._is_chunked(self.queue[0]):
                # long prompt: occupy a slot now, prefill it chunk-by-chunk
                # interleaved with decode (see _advance_prefills) — no
                # power-of-two mega-bucket is compiled for it. Reserve mode
                # budgets the full worst case up front; optimistic mode
                # admits on the resume prompt and lets chunk growth preempt
                # under pressure.
                if not self._fits_pages(
                        [self._admission_rows(self.queue[0])]):
                    return  # wait: retirements release budgeted pages
                req = self.queue.pop(0)
                if self.admission == "reserve":
                    self.allocator.reserve(free[0], self._worst_rows(req))
                self._mark_admitted(req, time.perf_counter())
                req.status = RequestStatus.PREFILLING
                # a reused slot's cache pos is stale from its previous
                # tenant; chunk writes derive their rows from it, so the
                # slot must restart at 0 before the first chunk
                self.cache["pos"] = self.cache["pos"].at[free[0]].set(0)
                self._place_cache()
                resume = self._resume_prompt(req)
                self.prefilling[free[0]] = {
                    "req": req,
                    "tokens": resume,
                    "chunks": plan_chunks(len(resume), self.prefill_chunk),
                    "next": 0,
                }
                continue
            if self.bucket_prompts:
                lens = []
                for r in self.queue:
                    # FCFS: never reorder past a chunked prompt, and keep
                    # warm-prefix requests out of the cold batch — they are
                    # admitted via _admit_warm when they reach the head
                    if self._is_chunked(r) or (
                            r is not self.queue[0]
                            and self._match_prefix(r) is not None):
                        break
                    lens.append(len(self._resume_prompt(r)))
                n, L = plan_admission(lens, len(free),
                                      self.prefill_batch, self.min_bucket,
                                      self.max_len)
                if self.paged:
                    n = self._clamp_to_pool(self.queue, n)
                    if n == 0:
                        return
                    from repro.serving.bucketing import bucket_length
                    L = bucket_length(max(lens[:n]), self.min_bucket,
                                      self.max_len)
                take = [self.queue.pop(0) for _ in range(n)]
                if self.paged and self.admission == "reserve":
                    for req, slot in zip(take, free):
                        self.allocator.reserve(slot, self._worst_rows(req))
                Bp = self.prefill_batch
                prompts = [self._resume_prompt(r) for r in take]
                tokens, last_pos = pad_prompts(prompts, Bp, L)
                t0 = time.perf_counter()
                logits, cacheN = self._call(
                    self._prefill, self.params, jnp.asarray(tokens),
                    jnp.asarray(last_pos))
                logits.block_until_ready()
                dt = time.perf_counter() - t0
                self._record_prefill((Bp, L))
                lens = np.asarray([len(p) for p in prompts], np.int32)
                slots = free[:n]
                if not self._splice_admitted(take, slots, cacheN, lens,
                                             retired):
                    continue
                for slot, p in zip(slots, prompts):
                    self._register_prefix(slot, p)
                sampling = [r.sampling for r in take] + [None] * (Bp - n)
                # a resumed request's next token is index len(generated),
                # NOT 0 — the fold_in(seed, i) contract is what makes the
                # post-preemption stream identical under stochastic
                # sampling too (fresh requests: len(generated) == 0)
                counters = ([len(r.generated) for r in take]
                            + [0] * (Bp - n))
                toks = np.asarray(sample_tokens(
                    logits[:, 0], *sampling_arrays(sampling, counters)))
                self._assign(take, slots, toks[:n], t0 + dt, dt, retired)
            else:
                # exact-length single-request prefill (recurrent mixers etc.)
                if self.paged:
                    if not self._fits_pages(
                            [self._admission_rows(self.queue[0])]):
                        return
                    if self.admission == "reserve":
                        self.allocator.reserve(
                            free[0], self._worst_rows(self.queue[0]))
                req = self.queue.pop(0)
                resume = self._resume_prompt(req)
                t0 = time.perf_counter()
                logits, cache1 = self._call(
                    self._prefill, self.params,
                    jnp.asarray(resume[None]),
                    jnp.asarray([len(resume) - 1], jnp.int32))
                logits.block_until_ready()
                dt = time.perf_counter() - t0
                self._record_prefill((1, len(resume)))
                lens1 = np.asarray([len(resume)], np.int32)
                if not self._splice_admitted([req], free[:1], cache1, lens1,
                                             retired):
                    continue
                self._register_prefix(free[0], resume)
                tok = np.asarray(sample_tokens(
                    logits[:, 0], *sampling_arrays(
                        [req.sampling], [len(req.generated)])))
                self._assign([req], free[:1], tok[:1], t0 + dt, dt, retired)

    def _advance_prefills(self, retired: List[Request]):
        """Feed the next chunk to every prefilling slot — ONE batched
        ``extend`` dispatch at a single compiled shape (slots, chunk); tail
        chunks are right-padded and neutralised by the paged write's valid
        mask. Slots whose prompt completes sample their first token and
        join the decode batch."""
        if not self.prefilling:
            return
        C = self._chunk_width
        # growth (and any copy-on-write the chunk's rows need) first, on a
        # snapshot: claiming pages for one slot may PREEMPT another
        # prefilling slot under pressure, mutating self.prefilling mid-walk
        for s in list(self.prefilling):
            if s not in self.prefilling:
                continue  # preempted by an earlier slot's growth
            st = self.prefilling[s]
            start, end = st["chunks"][st["next"]]
            self._ensure_resident(s, end)
            if s in self.prefilling:
                self._cow_for_write(s, start, end)
        if not self.prefilling:
            return
        tokens = np.zeros((self.slots, C), np.int32)
        valid = np.zeros((self.slots,), np.int32)
        for s, st in self.prefilling.items():
            start, end = st["chunks"][st["next"]]
            tokens[s, :end - start] = st["tokens"][start:end]
            valid[s] = end - start
        self._sync_page_table()
        t0 = time.perf_counter()
        logits, self.cache = self._call(
            self._extend, self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(valid))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.prefill_chunk_calls += 1
        finishing = []
        for s, st in list(self.prefilling.items()):
            st["next"] += 1
            # chunk wall time accrues on the requests riding THIS call,
            # once per chunk (prefill_time is += everywhere, never =)
            st["req"].prefill_time += dt
            if st["next"] >= len(st["chunks"]):
                finishing.append(s)
        if not finishing:
            return
        sampling = [None] * self.slots
        counters = [0] * self.slots
        for s in finishing:
            req = self.prefilling[s]["req"]
            sampling[s] = req.sampling
            counters[s] = len(req.generated)  # != 0 for resumed requests
        toks = np.asarray(sample_tokens(
            logits[:, 0], *sampling_arrays(sampling, counters)))
        now = time.perf_counter()
        for s in finishing:
            st = self.prefilling.pop(s)
            # all prompt rows are written now — publish them (warm slots
            # add their LONGER prefixes on top of the entries they hit)
            self._register_prefix(s, st["tokens"])
            self._occupy(st["req"], s, int(toks[s]), now, retired)

    # ------------------------------------------------------------ retirement
    def _terminate(self, req: Request, slot: Optional[int],
                   status: RequestStatus, retired: List[Request],
                   now: Optional[float] = None):
        """Move ``req`` to a terminal status, freeing its slot and pages
        if resident. The single exit point for every lifecycle outcome."""
        if slot is not None:
            self._evict(slot)
        req.status = status
        req.done = True
        req.t_done = now if now is not None else time.perf_counter()
        self.finished.append(req)
        retired.append(req)

    def _maybe_retire(self, slot: int, tok: int, retired: List[Request]):
        req = self.active[slot]
        hit_eos = self.eos_id is not None and tok == self.eos_id
        if len(req.generated) >= req.max_new_tokens or hit_eos:
            self._terminate(req, slot, RequestStatus.FINISHED, retired)

    def _sweep_lifecycle(self, retired: List[Request]):
        """Step-boundary enforcement of cancellation and deadlines, over
        the queue and every resident slot. Deadlines are measured from
        t_submit, so time spent queued (including requeued after
        preemption) counts against the budget."""
        if not self._cancel_uids and not any(
                r.deadline_s is not None for r in self._all_requests()):
            return
        now = time.perf_counter()

        def fate(req: Request) -> Optional[RequestStatus]:
            if req.uid in self._cancel_uids:
                self._cancel_uids.discard(req.uid)
                return RequestStatus.CANCELLED
            if (req.deadline_s is not None
                    and now - req.t_submit > req.deadline_s):
                return RequestStatus.EXPIRED
            return None

        keep = []
        for req in self.queue:
            status = fate(req)
            if status is None:
                keep.append(req)
            else:
                self._terminate(req, None, status, retired, now)
        self.queue = keep
        residents = [(s, req) for s, req in self.active.items()]
        residents += [(s, st["req"]) for s, st in self.prefilling.items()]
        for s, req in residents:
            status = fate(req)
            if status is not None:
                self._terminate(req, s, status, retired, now)
        self._cancel_uids.clear()  # unknown-by-now uids don't linger

    def _all_requests(self):
        for r in self.queue:
            yield r
        for r in self.active.values():
            yield r
        for st in self.prefilling.values():
            yield st["req"]

    # --------------------------------------------------------------- decode
    def _grow_pages_for_decode(self):
        """Paged layouts only: grow any slot whose next decode write crosses
        into an unallocated page, then push the table to the device.
        Growth under pressure preempts the latest-admitted other resident
        (:meth:`_ensure_resident`) — iteration runs on a snapshot because a
        preempted victim may be a slot later in the walk. Contiguous
        layouts are a no-op — the ring is pre-provisioned."""
        if not self.paged:
            return
        for s, req in list(self.active.items()):
            if s in self.active:  # not preempted by an earlier growth
                rows = len(req.prompt) + len(req.generated)
                self._ensure_resident(s, rows)
                if s in self.active:
                    # this step's decode write lands at row rows-1; if that
                    # page is shared (a just-registered prompt's partial
                    # boundary page, or a co-owned prefix) copy it first
                    self._cow_for_write(s, rows - 1, rows)
        self._sync_page_table()

    def _decode_dispatch(self):
        """One decode dispatch, layout-agnostic. Paged layouts decode via a
        single-token ``extend`` (dead and still-prefilling slots frozen with
        valid=0); contiguous layouts via the dedicated decode step. Both run
        under the serving mesh (if any) through :meth:`_call`, so the same
        path covers single-device and expert-parallel engines."""
        tok = jnp.asarray(self.last_token)
        if self.paged:
            logits, self.cache = self._call(
                self._extend, self.params, tok, self.cache,
                jnp.asarray(self.slot_live.astype(np.int32)))
        else:
            logits, self.cache = self._call(
                self._decode, self.params, tok, self.cache)
        return logits

    def step(self) -> List[Request]:
        """One engine step: admit waiting requests, decode one token for
        every live slot, retire finished requests. Returns the requests
        that finished during this step.

        Wall time accrues HERE (not in :meth:`run`), so engines driven
        step-by-step report the same ``wall_time_s``/``tokens_per_s`` as
        engines driven through :meth:`run`."""
        t0 = time.perf_counter()
        try:
            retired: List[Request] = []
            fault_step = self.engine_steps  # monotone even on prefill-only
            self.engine_steps += 1          # steps (decode_steps is not)
            if self.faults is not None:
                stall = self.faults.stall_now(fault_step)
                if stall:
                    time.sleep(stall)
            self._sweep_lifecycle(retired)
            self._admit(retired)
            if self.paged:
                self._advance_prefills(retired)
            # Injected preemption needs >= 2 residents: LIFO victim choice
            # then never touches the oldest-admitted request, which makes
            # progress every step — the forward-progress guarantee that
            # keeps chaos runs terminating. (Preempting a lone resident
            # frees pages for nobody and can livelock a chunked prefill
            # longer than the injection period.)
            if (self.faults is not None
                    and len(self.active) + len(self.prefilling) >= 2
                    and self.faults.preempt_now(fault_step)):
                victim = self._preempt_victim()
                if victim is not None:
                    self._preempt(victim)
            if not self.slot_live.any():
                return retired
            if self.spec is not None:
                # speculative decode phase: draft k tokens with the merged
                # draft model, verify them in ONE batched extend, emit the
                # accepted run (+ the target's own token at the first
                # mismatch), roll back the rest — token-identical to the
                # non-speculative stream by the seeded-acceptance rule
                self.spec.round(self, retired)
                return retired
            self._grow_pages_for_decode()
            t_dec = time.perf_counter()
            logits = self._decode_dispatch()
            logits.block_until_ready()
            self._decode_time += time.perf_counter() - t_dec
            rows = logits[:, 0]
            if self.faults is not None:
                for s, req in self.active.items():
                    if self.slot_live[s] and self.faults.poison_now(
                            req.uid, len(req.generated)):
                        rows = rows.at[s].set(jnp.nan)
            sampling = [self.active[s].sampling if self.slot_live[s] else None
                        for s in range(self.slots)]
            counters = [len(self.active[s].generated) if self.slot_live[s]
                        else 0 for s in range(self.slots)]
            next_tokens = np.asarray(sample_tokens(
                rows, *sampling_arrays(sampling, counters)))
            finite = (np.asarray(finite_rows(rows)) if self.logit_guard
                      else None)
            self.decode_steps += 1
            for slot, req in list(self.active.items()):
                if finite is not None and not finite[slot]:
                    # quarantine, don't crash the batch: the slot frees,
                    # the other requests keep decoding
                    req.error = (f"non-finite logits at decode step "
                                 f"{self.decode_steps} (token "
                                 f"{len(req.generated)})")
                    self._terminate(req, slot, RequestStatus.FAILED,
                                    retired)
                    continue
                tok = int(next_tokens[slot])
                req.generated.append(tok)
                self.last_token[slot, 0] = tok
                self._maybe_retire(slot, tok, retired)
            return retired
        finally:
            dt = time.perf_counter() - t0
            self._run_time += dt
            self._max_step_s = max(self._max_step_s, dt)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive the engine until the queue and all slots drain (or
        ``max_steps``). Returns every request that finished during this
        call, in retirement order. Wall time is accumulated by each
        :meth:`step` (not double-counted here)."""
        finished: List[Request] = []
        steps = 0
        while (self.queue or self.slot_live.any() or self.prefilling) \
                and steps < max_steps:
            finished.extend(self.step())
            steps += 1
        return finished

    # ------------------------------------------------------------ telemetry
    def _jit_prefill_cache_size(self) -> Optional[int]:
        try:
            return int(self._prefill._cache_size())
        except Exception:  # noqa: BLE001 - private jax API may move
            return None

    def reset_stats(self):
        """Clear telemetry accumulators (typically after a warm-up run that
        paid the compile cost). Compiled executables are kept, but they drop
        out of the :meth:`prefill_compilations` window: both the observed
        prefill shape set and the jit-cache baseline restart here, so
        post-reset stats begin clean."""
        self.finished = []
        self.prefill_calls = 0
        self.prefill_chunk_calls = 0
        self.prefill_shapes = set()
        self.decode_steps = 0
        self._run_time = 0.0
        self._decode_time = 0.0
        self._max_step_s = 0.0
        self._kv_pages_peak = (self.allocator.pages_in_use if self.paged
                               else 0)
        self._prefill_cache_base = self._jit_prefill_cache_size() or 0
        self.preemption_count = 0
        self._requeue_waits = []
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_rows_reused = 0
        if self.paged:
            self._evict_base = self.allocator.evictions
            self._cow_base = self.allocator.cow_count
        if self.spec is not None:
            self.spec.reset_counters()

    def prefill_compilations(self) -> int:
        """Distinct prefill executables compiled since the last
        :meth:`reset_stats` (or engine construction)."""
        n = self._jit_prefill_cache_size()
        if n is not None:
            return n - self._prefill_cache_base
        return len(self.prefill_shapes)

    def expert_bytes_per_device(self) -> dict:
        """Per-device MoE expert-parameter bytes of the SERVED params (after
        any EP padding/sharding) — ``{"total", "per_device",
        "max_per_device"}``; see
        :func:`repro.parallel.sharding.expert_param_bytes_per_device`."""
        from repro.parallel.sharding import expert_param_bytes_per_device

        return expert_param_bytes_per_device(self.params)

    def _page_bytes_per_device(self) -> int:
        """Per-device bytes of one KV page under the serving mesh. The K/V
        payload splits across ``_kv_shards`` devices (head- or head_dim-
        sharded per :func:`choose_kv_spec`); the int32 ``kv_pos`` row
        (page_size * 4 bytes) is replicated on every device."""
        full = paged_kv_page_bytes(self.cfg, self.page_size)
        pos_b = self.page_size * 4
        return (full - pos_b) // self._kv_shards + pos_b

    def kv_memory(self) -> dict:
        """KV memory accounting: what this engine actually holds vs what the
        contiguous layout provisions for the same ``(slots, max_len)``.
        ``*_per_device`` fields report the per-shard footprint under the
        serving mesh (equal to the global value when unsharded)."""
        contig = contiguous_kv_bytes(self.cfg, self.slots, self.max_len)
        if not self.paged:
            return {"layout": "contiguous",
                    "kv_shard_degree": self._kv_shards,
                    "kv_bytes_provisioned": contig,
                    "kv_bytes_contiguous": contig}
        page_b = paged_kv_page_bytes(self.cfg, self.page_size)
        page_b_dev = self._page_bytes_per_device()
        return {
            "layout": "paged",
            "page_size": self.page_size,
            "page_bytes": page_b,
            "page_bytes_per_device": page_b_dev,
            "kv_shard_degree": self._kv_shards,
            "pages_total": self.allocator.num_pages - 1,
            # unique mapped pages: a prefix page shared by k slots counts
            # once, so peak/per-device bytes never double-count shared KV
            "pages_in_use": self.allocator.pages_in_use,
            "pages_cached": self.allocator.pages_cached,
            "pages_peak": self._kv_pages_peak,
            "kv_bytes_provisioned": self.allocator.num_pages * page_b,
            "kv_bytes_peak": self._kv_pages_peak * page_b,
            "kv_bytes_peak_per_device": self._kv_pages_peak * page_b_dev,
            "kv_bytes_cached": self.allocator.pages_cached * page_b,
            "kv_bytes_contiguous": contig,
        }

    def stats(self) -> ServingStats:
        """Aggregate telemetry over every request retired so far. Means
        skip NaN per-request values (never-admitted or zero-token
        requests report NaN rather than a fake 0.0, see
        :class:`Request`), so a cancelled-while-queued request doesn't
        drag mean TTFT toward zero."""
        reqs = self.finished
        tokens = sum(len(r.generated) for r in reqs)
        pages_total = (self.allocator.num_pages - 1) if self.paged else 0
        page_bytes = (paged_kv_page_bytes(self.cfg, self.page_size)
                      if self.paged else 0)
        lookups = self.prefix_hits + self.prefix_misses
        return ServingStats(
            requests=len(reqs),
            total_new_tokens=tokens,
            wall_time_s=self._run_time,
            tokens_per_s=tokens / self._run_time if self._run_time else 0.0,
            mean_ttft_s=_nanmean(r.ttft for r in reqs),
            mean_queue_s=_nanmean(r.queue_time for r in reqs),
            mean_prefill_s=_nanmean(r.prefill_time for r in reqs),
            prefill_calls=self.prefill_calls,
            prefill_compilations=self.prefill_compilations(),
            decode_steps=self.decode_steps,
            decode_time_s=self._decode_time,
            decode_step_ms=(self._decode_time * 1e3 / self.decode_steps
                            if self.decode_steps else 0.0),
            prefill_chunk_calls=self.prefill_chunk_calls,
            max_step_s=self._max_step_s,
            kv_pages_total=pages_total,
            kv_pages_in_use=(self.allocator.pages_in_use if self.paged
                             else 0),
            kv_pages_peak=self._kv_pages_peak,
            kv_page_util=(self._kv_pages_peak / pages_total
                          if pages_total else 0.0),
            kv_bytes_peak=self._kv_pages_peak * page_bytes,
            kv_bytes_contiguous=contiguous_kv_bytes(
                self.cfg, self.slots, self.max_len),
            kv_shard_degree=self._kv_shards,
            kv_bytes_peak_per_device=(
                self._kv_pages_peak * self._page_bytes_per_device()
                if self.paged else 0),
            preemptions=self.preemption_count,
            mean_requeue_wait_s=(float(np.mean(self._requeue_waits))
                                 if self._requeue_waits else 0.0),
            cancelled=sum(r.status is RequestStatus.CANCELLED
                          for r in reqs),
            expired=sum(r.status is RequestStatus.EXPIRED for r in reqs),
            failed=sum(r.status is RequestStatus.FAILED for r in reqs),
            prefix_hits=self.prefix_hits,
            prefix_misses=self.prefix_misses,
            prefix_hit_rate=(self.prefix_hits / lookups if lookups
                             else 0.0),
            prefix_rows_reused=self.prefix_rows_reused,
            # rows served from shared pages are KV the pool did NOT store
            # (or recompute) a second time
            kv_bytes_saved=(self.prefix_rows_reused * page_bytes
                            // self.page_size if self.paged else 0),
            kv_pages_cached=(self.allocator.pages_cached if self.paged
                             else 0),
            mean_ttft_warm_s=_nanmean(
                r.ttft for r in reqs if r.prefix_rows > 0),
            mean_ttft_cold_s=_nanmean(
                r.ttft for r in reqs if r.prefix_rows == 0),
            prefix_evictions=(self.allocator.evictions - self._evict_base
                              if self.paged else 0),
            cow_copies=(self.allocator.cow_count - self._cow_base
                        if self.paged else 0),
            spec_rounds=self.spec.rounds if self.spec else 0,
            draft_tokens=self.spec.proposed if self.spec else 0,
            draft_accepted=self.spec.accepted if self.spec else 0,
            acceptance_rate=(self.spec.accepted / self.spec.proposed
                             if self.spec and self.spec.proposed else 0.0),
            spec_tokens_per_round=(self.spec.emitted / self.spec.slot_rounds
                                   if self.spec and self.spec.slot_rounds
                                   else 0.0),
            draft_time_s=self.spec.draft_time if self.spec else 0.0,
        )
