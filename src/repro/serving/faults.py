"""Deterministic fault injection for the serving engine.

Chaos testing for the request lifecycle: every failure path the engine
claims to survive (KV-pool exhaustion, forced preemption, poisoned
logits, splice failures, stalled steps) can be driven on purpose from a
seeded :class:`FaultConfig` armed via ``ServingConfig(faults=...)``.
Injection is host-side only — no fault ever touches compiled code — so
an injected run is reproducible given the same workload and seed, and
under greedy sampling must stay token-identical to an undisturbed run
(the correctness oracle used by the chaos tests and CI smoke).

Injection points (all consulted by ``ServingEngine``):

- ``preempt_now(step)``: force-preempt the latest-admitted resident
  request at a step boundary (``preempt_every`` deterministic cadence
  and/or ``preempt_prob`` seeded coin flip).
- ``exhaust_now()``: make a page-growth ``ensure`` behave as if the
  allocator were out of pages, exercising the preemption-on-exhaustion
  path without actually shrinking the pool.
- ``poison_now(uid, n_generated)``: overwrite one request's decode
  logits with NaN once it has generated ``poison_after`` tokens,
  exercising the logit guard's quarantine path.
- ``splice_fail_now(uids)``: raise from the prefill→cache splice for a
  chosen request, exercising admission failure handling.
- ``stall_now(step)``: sleep inside chosen engine steps, exercising the
  ``max_step_s`` telemetry and deadline enforcement.

Every fired fault is appended to ``FaultInjector.events`` so tests can
assert that the chaos they asked for actually happened.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class FaultConfig:
    """Declarative description of the faults to inject.

    All knobs default to "off"; a default-constructed config injects
    nothing. ``seed`` drives the probabilistic knobs (``preempt_prob``,
    ``exhaust_prob``) through a private ``RandomState`` so runs are
    reproducible.
    """

    seed: int = 0
    # Preempt the latest-admitted resident request every N engine steps
    # (0 disables) and/or with probability p per step. The engine skips
    # the injection unless >= 2 requests are resident: preempting a lone
    # resident frees pages for nobody and could starve a chunked prefill
    # forever (forward-progress guarantee).
    preempt_every: int = 0
    preempt_prob: float = 0.0
    # Probability that a page-growth ``ensure`` is treated as exhausted.
    exhaust_prob: float = 0.0
    # Overwrite these uids' decode logits with NaN (once each) after
    # they have generated >= ``poison_after`` tokens.
    poison_uids: Tuple[int, ...] = ()
    poison_after: int = 1
    # Raise from the prefill->cache splice for these uids (once each).
    splice_fail_uids: Tuple[int, ...] = ()
    # Sleep ``stall_s`` seconds inside these engine step indices.
    stall_steps: Tuple[int, ...] = ()
    stall_s: float = 0.02

    def validate(self) -> None:
        if self.preempt_every < 0:
            raise ValueError("preempt_every must be >= 0")
        for name in ("preempt_prob", "exhaust_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.stall_s < 0.0:
            raise ValueError("stall_s must be >= 0")


@dataclass
class FaultEvent:
    """One fired fault: ``kind`` plus the site it hit."""

    kind: str
    step: int = -1
    uid: int = -1


class FaultInjector:
    """Stateful, seeded driver for a :class:`FaultConfig`.

    One injector lives per engine; its RNG stream advances only when a
    probabilistic knob is consulted, so a run is deterministic given
    the workload, the config, and the seed.
    """

    def __init__(self, config: FaultConfig):
        config.validate()
        self.config = config
        self._rng = np.random.RandomState(config.seed)
        self._poisoned: set = set()
        self._splice_failed: set = set()
        self.events: List[FaultEvent] = []

    def _fire(self, kind: str, *, step: int = -1, uid: int = -1) -> bool:
        self.events.append(FaultEvent(kind=kind, step=step, uid=uid))
        return True

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    # -- injection points ------------------------------------------------

    def preempt_now(self, step: int) -> bool:
        """Should the engine force-preempt at this step boundary?"""
        cfg = self.config
        every = cfg.preempt_every
        if every and (step + 1) % every == 0:
            return self._fire("preempt", step=step)
        if cfg.preempt_prob and self._rng.rand() < cfg.preempt_prob:
            return self._fire("preempt", step=step)
        return False

    def exhaust_now(self) -> bool:
        """Should this page-growth ``ensure`` pretend the pool is dry?"""
        cfg = self.config
        if cfg.exhaust_prob and self._rng.rand() < cfg.exhaust_prob:
            return self._fire("exhaust")
        return False

    def poison_now(self, uid: int, n_generated: int) -> bool:
        """Should this request's decode logits be poisoned this step?"""
        cfg = self.config
        if (
            uid in cfg.poison_uids
            and uid not in self._poisoned
            and n_generated >= cfg.poison_after
        ):
            self._poisoned.add(uid)
            return self._fire("poison", uid=uid)
        return False

    def splice_fail_now(self, uids: Sequence[int]) -> int:
        """Return a uid from ``uids`` whose splice should fail, or -1."""
        for uid in uids:
            if (
                uid in self.config.splice_fail_uids
                and uid not in self._splice_failed
            ):
                self._splice_failed.add(uid)
                self._fire("splice_fail", uid=uid)
                return uid
        return -1

    def stall_now(self, step: int) -> float:
        """Seconds to sleep inside this engine step (0.0 = no stall)."""
        if step in self.config.stall_steps:
            self._fire("stall", step=step)
            return self.config.stall_s
        return 0.0


class InjectedFault(RuntimeError):
    """Raised at an injection point standing in for a real failure."""
