"""Prompt-length bucketing for compile-count-bounded prefill.

JAX recompiles the prefill function for every distinct ``(batch, seq_len)``
shape. A naive engine therefore compiles once per distinct prompt length —
unbounded on real traffic. Bucketing right-pads every prompt batch to the
next power of two (floored at ``min_bucket``, capped at ``max_len``), so a
mixed-length workload compiles at most ``O(log2(max_len))`` prefill shapes.

Correctness of right padding (no special mask plumbing needed):

* causal attention: a real token at position ``i`` only attends positions
  ``<= i``; padding sits strictly AFTER every real token, so the hidden
  state at each row's true last position is bit-identical to an unpadded
  prefill. Logits are gathered there via ``prefill(..., last_pos=...)``.
* the KV cache, however, does get garbage entries at padded positions; the
  engine neutralises them after splicing by setting their ``kv_pos`` to -1
  (the "unfilled slot" sentinel every decode mask already honours).

Recurrent mixers (mamba/xLSTM) fold padded tokens into their O(1) state and
local attention with a window smaller than the bucket drops real tokens from
the ring buffer, so bucketing is only offered where it is exact — see
:func:`supports_bucketing`.

Preemption resume (docs/serving_lifecycle.md) re-prefills a victim's
``prompt + generated`` tokens through these same buckets: resumed lengths
grow past the original prompt's bucket, but stay bounded by ``max_len``, so
the O(log2(max_len)) compile-count bound is unchanged under churn.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

BUCKETABLE_MIXERS = ("attn", "attn_global", "attn_local", "mla")


def bucket_length(n: int, min_bucket: int = 8, max_len: int = 1 << 30) -> int:
    """Smallest power of two >= n, floored at min_bucket, capped at max_len."""
    if n < 1:
        raise ValueError(f"prompt length {n} < 1")
    b = max(min_bucket, 1 << int(np.ceil(np.log2(max(n, 1)))))
    if n > max_len:
        raise ValueError(f"prompt length {n} exceeds max_len {max_len}")
    return min(b, max_len)


def num_buckets(max_len: int, min_bucket: int = 8) -> int:
    """Upper bound on distinct bucket lengths for prompts up to max_len."""
    n, count = min_bucket, 1
    while n < max_len:
        n *= 2
        count += 1
    return count


def pad_prompts(prompts: Sequence[np.ndarray], batch: int, length: int,
                pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Right-pad ``prompts`` into a fixed (batch, length) token matrix.

    Rows beyond ``len(prompts)`` are dummy (all pad_id) so the batch
    dimension also stays at one compiled size. Returns (tokens, last_pos)
    where last_pos[i] is the index of row i's final real token (0 for dummy
    rows — harmless, their logits are discarded).
    """
    if len(prompts) > batch:
        raise ValueError(f"{len(prompts)} prompts > batch {batch}")
    tokens = np.full((batch, length), pad_id, np.int32)
    last_pos = np.zeros((batch,), np.int32)
    for i, p in enumerate(prompts):
        if len(p) > length:
            raise ValueError(f"prompt length {len(p)} > bucket {length}")
        tokens[i, :len(p)] = p
        last_pos[i] = len(p) - 1
    return tokens, last_pos


def plan_chunks(prompt_len: int, chunk: int) -> List[Tuple[int, int]]:
    """Chunk spans ``[(start, end), ...]`` for chunked prefill: full
    ``chunk``-token spans plus a final ragged tail (the engine right-pads
    the tail to ``chunk`` so every chunk call compiles at ONE shape; padded
    rows are neutralised by the paged write's valid mask). Replaces the
    power-of-two bucket blowup for long prompts: a 4k-token prompt costs
    ceil(4k/chunk) calls of one shape instead of a dedicated 4k bucket."""
    if prompt_len < 1:
        raise ValueError(f"prompt length {prompt_len} < 1")
    if chunk < 1:
        raise ValueError(f"chunk {chunk} < 1")
    return [(s, min(s + chunk, prompt_len))
            for s in range(0, prompt_len, chunk)]


def supports_bucketing(cfg, max_len: int) -> bool:
    """True when right-padded prefill is exact for this architecture.

    Requires: attention-family mixers only (recurrent state would absorb the
    padding), no encoder/VLM inputs, and every sliding window at least
    ``max_len`` (a shorter ring buffer would evict real tokens in favour of
    padding when filling the cache from a padded prefill).
    """
    if cfg.family in ("encdec", "vlm"):
        return False
    mixers = {s.mixer for s in cfg.pattern}
    if not mixers <= set(BUCKETABLE_MIXERS):
        return False
    if "attn_local" in mixers and cfg.sliding_window \
            and cfg.sliding_window < max_len:
        return False
    return True


def plan_admission(prompt_lens: List[int], free_slots: int, batch: int,
                   min_bucket: int, max_len: int) -> Tuple[int, int]:
    """(n_admit, bucket) for the next batched prefill call.

    Greedy FCFS: admit the queue head up to min(free_slots, batch) requests
    and pad them all to the bucket of the LONGEST admitted prompt (padding
    shorter prompts further is free — same compiled shape).
    """
    n = min(len(prompt_lens), free_slots, batch)
    if n == 0:
        return 0, 0
    return n, bucket_length(max(prompt_lens[:n]), min_bucket, max_len)
