"""Token sampling for serving: greedy / temperature / nucleus (top-p).

One jitted, vmapped sampler serves the whole engine batch with PER-REQUEST
parameters: each row carries its own (temperature, top_p, seed, counter).
Determinism contract: token ``i`` of a request is drawn with
``fold_in(PRNGKey(seed), i)`` — independent of slot assignment, batch
composition, and admission order, so a request replays identically across
engine configurations (asserted in tests/test_serving.py).

``temperature <= 0`` means greedy (argmax); the stochastic branch is still
computed under vmap but discarded by the final ``where`` — batch rows are
tiny, so uniformity of the compiled shape wins over skipping work.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """Per-request generation controls — the one user-facing knob bundle
    of the stable serving API (docs/serving_api.md). ``temperature`` /
    ``top_p`` / ``seed`` steer the sampler; ``max_new`` and ``deadline_s``,
    when set, override the corresponding :class:`Request` fields at
    construction so ``engine.generate(prompt, params)`` needs nothing
    else."""

    temperature: float = 0.0   # 0 -> greedy
    top_p: float = 1.0         # nucleus mass; 1.0 -> full distribution
    seed: int = 0
    max_new: int | None = None       # generation budget (tokens)
    deadline_s: float | None = None  # wall-clock budget from submission

    def __post_init__(self):
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.max_new is not None and self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {self.deadline_s}")


GREEDY = SamplingParams()


def _sample_one(logits, temperature, top_p, seed, counter):
    """logits (V,) -> sampled token id (int32)."""
    greedy_tok = jnp.argmax(logits).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), counter)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    probs = jax.nn.softmax(scaled)
    # nucleus filter: sort descending, keep the minimal prefix whose mass
    # reaches top_p (the first token is always kept)
    sorted_idx = jnp.argsort(-probs)
    sp = jnp.take(probs, sorted_idx)
    keep = (jnp.cumsum(sp) - sp) < top_p
    logp = jnp.where(keep, jnp.log(jnp.maximum(sp, 1e-38)), -jnp.inf)
    choice = jax.random.categorical(key, logp)
    sampled = jnp.take(sorted_idx, choice).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled)


@partial(jax.jit)
def finite_rows(logits):
    """Per-row health mask for the engine's logit guard: row b is True iff
    every entry of ``logits[b]`` is finite (no NaN/Inf). Computed in
    float32 so a bf16 overflow that round-trips to Inf is still caught."""
    return jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)


@partial(jax.jit)
def sample_tokens(logits, temperature, top_p, seed, counter):
    """Batched per-row sampling.

    logits (B, V); temperature/top_p float32 (B,); seed/counter int32 (B,).
    Returns (B,) int32 token ids.
    """
    return jax.vmap(_sample_one)(logits, temperature, top_p, seed, counter)


@partial(jax.jit)
def sample_tokens_grid(logits, temperature, top_p, seed, counters):
    """Per-position batched sampling — the speculative-decoding verifier's
    sampler.

    logits (B, C, V); temperature/top_p float32 (B,); seed int32 (B,);
    counters int32 (B, C) — the stream token index each position would
    emit at. Returns (B, C) int32 token ids.

    Position ``j`` of row ``b`` draws with ``fold_in(PRNGKey(seed[b]),
    counters[b, j])`` — EXACTLY the key :func:`sample_tokens` would use
    for that stream index. This is what makes seeded speculative
    acceptance lossless: the verifier's draw at index ``i`` is the same
    deterministic function of (logits, seed, i) as sequential decode's,
    so accepted drafts and the replacement token at the first mismatch
    reproduce the non-speculative stream bit-for-bit (greedy AND
    stochastic).
    """
    per_row = jax.vmap(_sample_one, in_axes=(0, None, None, None, 0))
    return jax.vmap(per_row)(logits, temperature, top_p, seed, counters)


def sampling_arrays(params_list, counters):
    """Pack per-request SamplingParams + token counters into device-ready
    arrays for :func:`sample_tokens`. ``params_list`` entries may be None
    (dead slot / dummy row) -> greedy with seed 0."""
    n = len(params_list)
    temp = np.zeros((n,), np.float32)
    top_p = np.ones((n,), np.float32)
    seed = np.zeros((n,), np.int32)
    for i, sp in enumerate(params_list):
        if sp is None:
            continue
        temp[i] = sp.temperature
        top_p[i] = sp.top_p
        seed[i] = sp.seed
    return (jnp.asarray(temp), jnp.asarray(top_p), jnp.asarray(seed),
            jnp.asarray(np.asarray(counters, np.int32)))
