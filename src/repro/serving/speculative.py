"""Speculative decoding with a MergePlan-derived draft model.

HC-SMoE's merged models trade quality for memory; speculative decoding
inverts that trade. An aggressively-merged :class:`~repro.core.plan.MergePlan`
builds a DRAFT model that shares the target's tokenizer, architecture, and
parameter provenance with zero draft training — ``apply_plan`` at engine
load is the whole draft-construction story — and the target verifies every
drafted token, so merged-model quality loss stops mattering while decode
still gets the merged model's speed.

One speculative **round** per engine step, replacing the per-token decode
dispatch (:meth:`SpecState.round`):

1. **sync** — slots whose draft cache is stale (fresh admission, preemption
   resume, slot reuse) re-prefill ``prompt + generated[:-1]`` through the
   draft model into the contiguous draft cache (one bucketed batched call).
2. **draft** — k batched draft ``decode_step`` calls propose
   ``d_1 .. d_k`` per live slot, each sampled with the request's OWN
   sampler at its true stream counter (token index ``g+j-1`` for ``d_j``).
3. **verify** — ONE batched target ``extend`` call (the chunked-prefill
   multi-token path, ``C = k+1``) feeds ``[last_token, d_1 .. d_r]`` and
   returns logits at every row (``all_logits=True``). Rows beyond each
   slot's per-round budget ``r = min(k, max_new - g - 1)`` are frozen by
   ``valid`` — the null-page write redirect keeps them off live pages.
4. **accept** — seeded rejection-sampling acceptance, degenerate-case
   exact: the engine's determinism contract makes token ``i`` a
   deterministic function ``sampler(logits, fold_in(seed, i))``, so the
   proposal distribution is a point mass and the classic
   ``min(1, p_target/p_draft)`` acceptance reduces to *equality of the
   seeded draws*. Draft ``d_j`` is accepted iff the target's own draw at
   counter ``g+j-1`` (from verify row ``j-1``) equals it; the first
   mismatch emits the target's draw instead (the "bonus" token after a
   fully-accepted run). By induction every emitted token equals the
   non-speculative stream — greedy AND stochastic, bit-for-bit (tested in
   tests/test_speculative.py).
5. **rollback** — rejected rows are erased from the target's paged cache
   (``kv_pos`` reset on the slot's own pages, ``pos`` rewound); the draft
   cache rewinds its ring the same way. ``_cow_for_write`` runs over the
   whole verify span first, so a rejected draft can never have dirtied a
   shared prefix-cache page.

The subsystem composes with the rest of the stack by construction: the
verify call IS the engine's extend path (paged × jnp/pallas × single/EP all
reuse their existing dispatch; under a mesh ``_verify`` is jitted with the
same shardings as ``_extend``), preemption invalidates per-slot draft sync
state which lazily re-syncs (streams stay token-identical because
acceptance is stream-deterministic), and prefix caching interacts only
through the COW barrier above. The draft model always runs unsharded on
the default device — it is small by construction (that is the point of the
aggressive plan), so replicating it costs less than sharding chatter.

See docs/serving_api.md (config surface) and docs/serving_lifecycle.md
(draft/verify/accept/rollback lifecycle).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.kvcache import init_cache
from repro.serving.bucketing import bucket_length, pad_prompts
from repro.serving.sampling import (
    finite_rows, sample_tokens, sample_tokens_grid, sampling_arrays)


@dataclass
class SpecConfig:
    """Speculative-decoding knobs for :class:`~repro.serving.engine.ServingConfig`.

    ``draft_plan`` names the draft model: a
    :class:`~repro.core.plan.MergePlan` (or a saved-plan directory for
    :func:`~repro.checkpoint.load_plan`) applied to the engine's BASE
    params at load time. The plan must have been computed against the same
    architecture and base checkpoint the engine serves — same tokenizer,
    vocab, and parameter structure — which every ``compress.py compute``
    plan satisfies by construction (docs/compression_api.md). ``k`` is the
    draft run length per round: each round costs k draft decode steps plus
    ONE target verify dispatch and emits between 1 and k+1 tokens.
    """

    draft_plan: object = None     # MergePlan | str (saved-plan directory)
    k: int = 4

    def validate(self) -> None:
        if self.draft_plan is None:
            raise ValueError(
                "SpecConfig.draft_plan is required: pass a MergePlan or a "
                "saved-plan directory (launch/compress.py compute)")
        if self.k < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")


def _rollback_ring(cache, new_pos):
    """Rewind a contiguous ring cache: ``pos`` drops to ``new_pos`` (B,)
    and every retained row at an absolute position >= its slot's new pos
    is neutralised (kv_pos -1). Ring offsets are position-determined
    (``pos % W``), so the next writes overwrite the stale payload rows."""
    def visit(path, leaf):
        top = path[0].key
        name = getattr(path[-1], "key", None)
        if top == "pos":
            return new_pos
        if name == "kv_pos":
            if top == "blocks":   # (nb, B, W)
                return jnp.where(leaf >= new_pos[None, :, None], -1, leaf)
            return jnp.where(leaf >= new_pos[:, None], -1, leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, cache)


class SpecState:
    """Draft-model runtime owned by a speculative engine.

    Holds the merged draft params, a contiguous (ring) draft KV cache with
    one row set per engine slot, and per-slot sync state ``slot -> (uid,
    n)`` recording that the draft cache holds exactly rows ``[0, n)`` of
    that request's stream (``n = len(prompt) + len(generated) - 1`` — the
    last sampled token lives in ``engine.last_token``, not the cache,
    matching the target's pos invariant). Any event that falsifies the
    record — admission of a new tenant, preemption/resume, retirement —
    is caught by the (uid, n) check and repaired lazily with a draft
    prefill; nothing needs to eagerly chase lifecycle transitions.
    """

    def __init__(self, engine, base_params, cfg: SpecConfig):
        cfg.validate()
        plan = cfg.draft_plan
        if isinstance(plan, str):
            from repro.checkpoint import load_plan

            plan = load_plan(plan)
        from repro.core.plan import apply_plan

        self.k = int(cfg.k)
        self.plan = plan
        self.draft_params = apply_plan(base_params, plan)
        model, moe_mode, max_len = engine.model, engine.moe_mode, \
            engine.max_len
        self.cache = init_cache(engine.cfg, engine.slots, max_len,
                                jnp.dtype(engine.cfg.dtype))
        # host mirror of the draft cache's per-slot pos; authoritative —
        # the device value is overwritten from it at every rollback
        self.draft_pos = np.zeros((engine.slots,), np.int32)
        self.synced: Dict[int, Tuple[int, int]] = {}

        # the draft always runs unsharded on the default device (pc=None,
        # no mesh): it is small by construction, and keeping it off the
        # serving mesh means EP composes with zero extra plumbing
        def d_prefill(p, tokens, last_pos):
            return model.prefill(p, tokens=tokens, last_pos=last_pos,
                                 moe_mode=moe_mode, cache_max_len=max_len,
                                 pc=None)

        def d_decode(p, tokens, cache):
            return model.decode_step(p, tokens=tokens, cache=cache,
                                     moe_mode=moe_mode, pc=None)

        self._d_prefill = jax.jit(d_prefill)
        self._d_decode = jax.jit(d_decode)
        self._d_rollback = jax.jit(_rollback_ring)
        self.reset_counters()

    def reset_counters(self):
        self.rounds = 0          # draft+verify rounds (1 target dispatch each)
        self.slot_rounds = 0     # per-slot verify participations
        self.proposed = 0        # draft tokens submitted for verification
        self.accepted = 0        # draft tokens the target accepted
        self.emitted = 0         # tokens emitted by rounds (accepted + bonus)
        self.draft_time = 0.0    # wall time in draft prefill/decode dispatches

    # ------------------------------------------------------------- sync
    def _invalidate(self, slot: int):
        self.synced.pop(slot, None)

    def _sync(self, eng, live: List[int]):
        """Bring every live slot's draft cache up to its stream: slots
        whose (uid, n) record mismatches re-prefill ``resume_prompt[:n]``
        through the draft model in one batched (bucketed) call and splice
        the rows into the draft ring. ``n >= 1`` always — a RUNNING
        request has a nonempty prompt and at least one generated token."""
        need: List[Tuple[int, int]] = []
        for s in live:
            req = eng.active[s]
            n = len(req.prompt) + len(req.generated) - 1
            if self.synced.get(s) == (req.uid, n):
                continue
            need.append((s, n))
        if not need:
            return
        from repro.serving.engine import splice_ring

        t0 = time.perf_counter()
        if eng.bucket_prompts:
            slots = [s for s, _ in need]
            prompts = [eng._resume_prompt(eng.active[s])[:n]
                       for s, n in need]
            L = bucket_length(max(n for _, n in need), eng.min_bucket,
                              eng.max_len)
            tokens, last_pos = pad_prompts(prompts, eng.slots, L)
            _, cacheN = self._d_prefill(self.draft_params,
                                        jnp.asarray(tokens),
                                        jnp.asarray(last_pos))
            lens = np.asarray([n for _, n in need], np.int32)
            self.cache = splice_ring(self.cache, slots, cacheN, lens)
        else:
            for s, n in need:
                prompt = eng._resume_prompt(eng.active[s])[:n]
                _, cache1 = self._d_prefill(
                    self.draft_params, jnp.asarray(prompt[None]),
                    jnp.asarray([n - 1], jnp.int32))
                self.cache = splice_ring(self.cache, [s], cache1,
                                         np.asarray([n], np.int32))
        jax.block_until_ready(self.cache["pos"])
        self.draft_time += time.perf_counter() - t0
        for s, n in need:
            self.draft_pos[s] = n
            self.synced[s] = (eng.active[s].uid, n)

    # ------------------------------------------------------------ draft
    def _draft(self, eng, steps: int) -> np.ndarray:
        """Propose ``steps`` tokens per live slot with the draft model:
        ``steps`` batched decode steps over the full slot batch (dead rows
        ride along and are discarded). Draft ``d_j`` is sampled with the
        request's own SamplingParams at stream counter ``g + j - 1`` —
        the index it would be emitted at — which is what the acceptance
        rule compares against. Returns drafts (k, slots) int32 with rows
        ``>= steps`` zero (never read: per-slot budgets are <= steps)."""
        drafts = np.zeros((self.k, eng.slots), np.int32)
        if steps == 0:
            return drafts
        sampling = [eng.active[s].sampling if s in eng.active else None
                    for s in range(eng.slots)]
        g0 = [len(eng.active[s].generated) if s in eng.active else 0
              for s in range(eng.slots)]
        tok = np.array(eng.last_token, np.int32)
        t0 = time.perf_counter()
        for j in range(steps):
            logits, self.cache = self._d_decode(self.draft_params,
                                                jnp.asarray(tok), self.cache)
            counters = [g + j for g in g0]
            drafts[j] = np.asarray(sample_tokens(
                logits[:, 0], *sampling_arrays(sampling, counters)))
            tok = drafts[j][:, None]
        # feed the LAST sampled draft too (logits discarded): on full
        # acceptance it joins the stream and next round decodes past it,
        # and its KV can only come from a decode over the existing draft
        # context — skipping this write would leave a hole the sync
        # record claims is filled, silently corrupting every draft after
        # a fully-accepted round
        _, self.cache = self._d_decode(self.draft_params,
                                       jnp.asarray(tok), self.cache)
        jax.block_until_ready(self.cache["pos"])
        self.draft_time += time.perf_counter() - t0
        return drafts

    # ------------------------------------------------------------ round
    def round(self, eng, retired: List) -> None:
        """One speculative round over the engine's live decode slots —
        the engine's whole decode phase when speculation is on."""
        from repro.serving.engine import RequestStatus

        live = [s for s in range(eng.slots) if eng.slot_live[s]]
        # per-slot draft budget: emissions (<= r+1) never exceed the
        # remaining token budget, and the verify write never crosses
        # row S + max_new - 1 < max_len — submit()'s bound still holds
        pos0: Dict[int, int] = {}
        budget: Dict[int, int] = {}
        for s in live:
            req = eng.active[s]
            g = len(req.generated)
            pos0[s] = len(req.prompt) + g - 1
            budget[s] = min(self.k, req.max_new_tokens - g - 1)
        self._sync(eng, [s for s in live if budget[s] > 0])
        drafts = self._draft(eng, max(budget.values(), default=0))

        # grow pages (and COW shared ones) over the whole verify span;
        # growth under pressure may preempt OTHER live slots mid-walk
        for s in live:
            if s not in eng.active:
                continue
            eng._ensure_resident(s, pos0[s] + budget[s] + 1)
            if s in eng.active:
                eng._cow_for_write(s, pos0[s], pos0[s] + budget[s] + 1)
        eng._sync_page_table()
        verifying = [s for s in live if s in eng.active]
        if not verifying:
            return

        C = self.k + 1
        tokens = np.zeros((eng.slots, C), np.int32)
        valid = np.zeros((eng.slots,), np.int32)
        counters = np.zeros((eng.slots, C), np.int32)
        for s in verifying:
            r = budget[s]
            tokens[s, 0] = eng.last_token[s, 0]
            tokens[s, 1:1 + r] = drafts[:r, s]
            valid[s] = r + 1
            g = len(eng.active[s].generated)
            counters[s] = g + np.arange(C)

        t_dec = time.perf_counter()
        logits, eng.cache = eng._call(
            eng._verify, eng.params, jnp.asarray(tokens), eng.cache,
            jnp.asarray(valid))
        logits.block_until_ready()
        eng._decode_time += time.perf_counter() - t_dec
        eng.decode_steps += 1
        self.rounds += 1

        if eng.faults is not None:
            for s in verifying:
                req = eng.active[s]
                if eng.faults.poison_now(req.uid, len(req.generated)):
                    logits = logits.at[s].set(jnp.nan)
        verifying_set = set(verifying)
        sampling = [eng.active[s].sampling if s in verifying_set else None
                    for s in range(eng.slots)]
        temp, top_p, seed, _ = sampling_arrays(sampling, [0] * eng.slots)
        tgt = np.asarray(sample_tokens_grid(logits, temp, top_p, seed,
                                            jnp.asarray(counters)))
        finite = (np.asarray(finite_rows(logits)) if eng.logit_guard
                  else None)  # (slots, C) per-row health

        rollback: List[Tuple[int, int]] = []   # (slot, rows kept)
        for s in verifying:
            req = eng.active[s]
            r = int(valid[s]) - 1
            if finite is not None and not finite[s, :r + 1].all():
                req.error = (f"non-finite logits at decode step "
                             f"{eng.decode_steps} (token "
                             f"{len(req.generated)})")
                self._invalidate(s)
                eng._terminate(req, s, RequestStatus.FAILED, retired)
                continue
            a = 0
            while a < r and drafts[a, s] == tgt[s, a]:
                a += 1
            emitted = [int(drafts[j, s]) for j in range(a)]
            emitted.append(int(tgt[s, a]))
            self.slot_rounds += 1
            self.proposed += r
            self.accepted += a
            consumed = 0
            for tok in emitted:
                req.generated.append(tok)
                eng.last_token[s, 0] = tok
                consumed += 1
                hit_eos = eng.eos_id is not None and tok == eng.eos_id
                if len(req.generated) >= req.max_new_tokens or hit_eos:
                    self._invalidate(s)
                    eng._terminate(req, s, RequestStatus.FINISHED, retired)
                    break
            self.emitted += consumed
            if s in eng.active:
                rollback.append((s, pos0[s] + consumed))
                # the draft's fed inputs up to the acceptance point WERE
                # the true stream, so its cache stays valid at n+consumed
                self.synced[s] = (req.uid,
                                  int(self.draft_pos[s]) + consumed)

        self._rollback_target(eng, rollback, pos0, valid)
        self._rollback_draft(eng, rollback)

    # --------------------------------------------------------- rollbacks
    def _rollback_target(self, eng, rollback: List[Tuple[int, int]],
                         pos0: Dict[int, int], valid: np.ndarray):
        """Erase rejected verify rows from the target's paged cache: the
        slot keeps rows ``[0, kept)``; rows ``[kept, pos0 + valid)`` —
        written by the verify extend on the slot's own (post-COW) pages —
        get their ``kv_pos`` reset and ``pos`` rewinds to ``kept``.
        Retired slots skip this: release already freed their exclusive
        pages (resetting kv_pos), and shared prefix pages only ever hold
        prompt rows the COW barrier kept the verify write away from."""
        if not rollback:
            return
        flat: List[int] = []
        page = eng.page_size
        for s, kept in rollback:
            owned = eng.allocator.owned(s)
            for rowpos in range(kept, pos0[s] + int(valid[s])):
                flat.append(owned[rowpos // page] * page + rowpos % page)
        if flat:
            kvp = eng.cache["kv_pos"]
            eng.cache["kv_pos"] = kvp.reshape(-1).at[
                jnp.asarray(np.asarray(flat, np.int32))].set(-1).reshape(
                kvp.shape)
        slots = np.asarray([s for s, _ in rollback], np.int32)
        kept = np.asarray([k for _, k in rollback], np.int32)
        eng.cache["pos"] = eng.cache["pos"].at[jnp.asarray(slots)].set(
            jnp.asarray(kept))
        eng._place_cache()

    def _rollback_draft(self, eng, rollback: List[Tuple[int, int]]):
        """Rewind the draft ring after a round. Every slot drafted up to
        ``k + 1`` rows past its sync point (the fed inputs plus the
        final sampled draft); surviving slots keep the rows matching
        accepted stream tokens (their fed inputs WERE the true stream up
        to the acceptance point), everyone else rewinds to its recorded
        sync pos — stale slots hold garbage a future resync replaces, so
        any in-range value is safe there.

        Ring-wrap caveat: a draft write that wrapped a ring (sliding
        window, or a near-``max_len`` stream drafting past its budget)
        evicted an old row the rewind cannot restore — that row stays
        masked. This degrades only DRAFT quality (acceptance rate near
        completion); emitted tokens are unaffected because the target
        verifies every one."""
        new_pos = self.draft_pos.copy()
        for s, _ in rollback:
            new_pos[s] = self.synced[s][1]
        self.draft_pos = new_pos
        self.cache = self._d_rollback(self.cache,
                                      jnp.asarray(new_pos))
