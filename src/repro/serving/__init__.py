from repro.models.kvcache import (  # noqa: F401
    PageAllocator, PageExhausted, supports_paging)
from repro.serving.bucketing import (  # noqa: F401
    bucket_length, num_buckets, plan_chunks, supports_bucketing)
from repro.serving.engine import (  # noqa: F401
    Request, RequestStatus, ServingConfig, ServingEngine, ServingStats)
from repro.serving.faults import (  # noqa: F401
    FaultConfig, FaultEvent, FaultInjector, InjectedFault)
from repro.serving.sampling import GREEDY, SamplingParams  # noqa: F401
