"""Stable serving surface.

``__all__`` below is the supported API (see docs/serving_api.md):
construct a :class:`ServingConfig`, hand it to :class:`ServingEngine`,
submit :class:`Request` objects (or use ``engine.generate``) with
:class:`SamplingParams`, and read :class:`ServingStats`. Everything else
importable from the submodules is internal and may change without notice.
"""
from repro.models.kvcache import (  # noqa: F401
    PageAllocator, PageExhausted, supports_paging)
from repro.serving.bucketing import (  # noqa: F401
    bucket_length, num_buckets, plan_chunks, supports_bucketing)
from repro.serving.engine import (  # noqa: F401
    Request, RequestStatus, ServingConfig, ServingEngine, ServingStats)
from repro.serving.faults import (  # noqa: F401
    FaultConfig, FaultEvent, FaultInjector, InjectedFault)
from repro.serving.sampling import GREEDY, SamplingParams  # noqa: F401
from repro.serving.speculative import SpecConfig  # noqa: F401

__all__ = [
    "Request",
    "RequestStatus",
    "ServingConfig",
    "ServingEngine",
    "ServingStats",
    "SpecConfig",
    "SamplingParams",
    "GREEDY",
    "FaultConfig",
    "FaultEvent",
    "FaultInjector",
    "InjectedFault",
    "PageAllocator",
    "PageExhausted",
]
