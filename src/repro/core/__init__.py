"""HC-SMoE: the paper's primary contribution.

Calibration (Eq. 4 expert-output stats) -> hierarchical clustering (Alg. 1)
-> weight-space merging (freq/avg/fix-dom/zipit) -> group-map router
redirect, plus every baseline the paper compares against.

The compression API is plan-based (``docs/compression_api.md``):
``compute_plan`` produces a serializable :class:`MergePlan`, ``apply_plan``
writes it into params; ``apply_hcsmoe``/``run_hcsmoe`` remain as shims.
"""
from repro.core.api import layer_weights, moe_positions  # noqa: F401
from repro.core.calibration import collect_moe_stats, flatten_stats  # noqa: F401
from repro.core.pipeline import HCSMoEConfig, apply_hcsmoe, run_hcsmoe  # noqa: F401
from repro.core.plan import (  # noqa: F401
    MergePlan, PlanMismatchError, PlanSpec, apply_plan, compute_plan,
    plan_summary)
from repro.core.registry import (  # noqa: F401
    register_clustering, register_merge, register_metric, register_planner)
