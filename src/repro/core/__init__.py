"""HC-SMoE: the paper's primary contribution.

Calibration (Eq. 4 expert-output stats) -> hierarchical clustering (Alg. 1)
-> weight-space merging (freq/avg/fix-dom/zipit) -> group-map router
redirect, plus every baseline the paper compares against.
"""
from repro.core.calibration import collect_moe_stats, flatten_stats  # noqa: F401
from repro.core.pipeline import HCSMoEConfig, apply_hcsmoe, run_hcsmoe  # noqa: F401
