"""Clustering algorithms for expert grouping (paper §3.2.2, Appendix B.5/D).

All algorithms are deterministic given their inputs (HC unconditionally; the
K-means/FCM variants given an explicit seed), run offline on (E, D) feature
matrices, and return integer labels in canonical order (clusters numbered by
first-member appearance) so downstream merging is reproducible bit-for-bit.

Each algorithm is registered in :data:`repro.core.registry.CLUSTERINGS`
under the uniform signature ``fn(feats, r, *, linkage, seed) -> (labels,
membership | None)`` — soft algorithms (FCM) return their membership matrix,
hard ones return ``None``. ``@register_clustering("name")`` makes a new
algorithm a valid ``clustering=`` value everywhere at once.
"""
from __future__ import annotations

import numpy as np

from repro.core.registry import CLUSTERINGS, register_clustering

LINKAGES = ("single", "complete", "average")


def pairwise_euclidean(feats: np.ndarray) -> np.ndarray:
    """(E, D) -> (E, E) Euclidean distances, float64 for determinism."""
    f = np.asarray(feats, np.float64)
    sq = np.sum(f * f, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (f @ f.T)
    return np.sqrt(np.maximum(d2, 0.0))


def canonical_labels(labels: np.ndarray) -> np.ndarray:
    """Renumber clusters by order of first appearance."""
    mapping = {}
    out = np.empty_like(labels)
    for i, l in enumerate(labels):
        if l not in mapping:
            mapping[l] = len(mapping)
        out[i] = mapping[l]
    return out


# ---------------------------------------------------------------------------
# Hierarchical agglomerative clustering (the paper's method)
# ---------------------------------------------------------------------------


def hierarchical_cluster(feats: np.ndarray, r: int,
                         linkage: str = "average") -> np.ndarray:
    """Bottom-up agglomerative clustering to ``r`` clusters (Alg. 1 lines
    5-11). Lance-Williams distance updates; deterministic lexicographic
    tie-breaking on the merged pair.
    """
    if linkage not in LINKAGES:
        raise ValueError(linkage)
    n = feats.shape[0]
    if not 1 <= r <= n:
        raise ValueError(f"target clusters {r} not in [1, {n}]")
    D = pairwise_euclidean(feats)
    np.fill_diagonal(D, np.inf)
    active = list(range(n))
    sizes = np.ones(n)
    labels = np.arange(n)

    for _ in range(n - r):
        # find the minimum-distance active pair, lexicographic tie-break
        sub = D[np.ix_(active, active)]
        flat = np.argmin(sub)
        ai, aj = divmod(flat, len(active))
        if ai > aj:
            ai, aj = aj, ai
        i, j = active[ai], active[aj]
        # Lance-Williams update of row i (absorbs j)
        for k in active:
            if k in (i, j):
                continue
            if linkage == "single":
                newd = min(D[i, k], D[j, k])
            elif linkage == "complete":
                newd = max(D[i, k], D[j, k])
            else:  # average (UPGMA)
                newd = (sizes[i] * D[i, k] + sizes[j] * D[j, k]) / (
                    sizes[i] + sizes[j])
            D[i, k] = D[k, i] = newd
        sizes[i] += sizes[j]
        labels[labels == labels[j]] = labels[i]
        active.remove(j)
        D[j, :] = D[:, j] = np.inf

    return canonical_labels(labels)


# ---------------------------------------------------------------------------
# K-means (fixed / random init) — the ablation baseline
# ---------------------------------------------------------------------------


def kmeans_cluster(feats: np.ndarray, r: int, init: str = "fix",
                   seed: int = 0, iters: int = 100) -> np.ndarray:
    f = np.asarray(feats, np.float64)
    n = f.shape[0]
    if init == "fix":
        centers = f[:r].copy()
    elif init == "rnd":
        rng = np.random.RandomState(seed)
        centers = f[rng.choice(n, r, replace=False)].copy()
    else:
        raise ValueError(init)
    labels = np.zeros(n, np.int64)
    for _ in range(iters):
        d2 = ((f[:, None, :] - centers[None]) ** 2).sum(-1)
        new_labels = np.argmin(d2, axis=1)
        if np.array_equal(new_labels, labels) and _ > 0:
            break
        labels = new_labels
        for c in range(r):
            members = f[labels == c]
            if len(members):
                centers[c] = members.mean(0)
    # guarantee r non-empty clusters: seed each empty cluster with a distinct
    # farthest point. Points already used as a reseed (or that are the sole
    # member of their cluster) are excluded, otherwise successive empty
    # clusters can claim the SAME farthest point and overwrite each other,
    # leaving fewer than r clusters.
    counts = np.bincount(labels, minlength=r)
    reseeded: list = []
    for c in range(r):
        if counts[c]:
            continue
        d2 = ((f - centers[labels]) ** 2).sum(-1)
        d2[reseeded] = -np.inf
        d2[counts[labels] <= 1] = -np.inf
        far = int(np.argmax(d2))
        counts[labels[far]] -= 1
        labels[far] = c
        counts[c] = 1
        reseeded.append(far)
    assert np.all(np.bincount(labels, minlength=r) > 0)
    return canonical_labels(labels)


# ---------------------------------------------------------------------------
# Fuzzy C-means (Appendix B.5) — soft clustering baseline
# ---------------------------------------------------------------------------


def fcm_cluster(feats: np.ndarray, r: int, m: float = 2.0, seed: int = 0,
                iters: int = 100, tol: float = 1e-6):
    """Returns (labels via argmax, membership matrix U (E, r))."""
    f = np.asarray(feats, np.float64)
    n = f.shape[0]
    rng = np.random.RandomState(seed)
    U = rng.rand(n, r)
    U /= U.sum(1, keepdims=True)
    for _ in range(iters):
        um = U ** m
        centers = (um.T @ f) / np.maximum(um.sum(0)[:, None], 1e-12)
        dist = np.sqrt(((f[:, None, :] - centers[None]) ** 2).sum(-1))
        dist = np.maximum(dist, 1e-12)
        inv = dist ** (-2.0 / (m - 1.0))
        U_new = inv / inv.sum(1, keepdims=True)
        if np.max(np.abs(U_new - U)) < tol:
            U = U_new
            break
        U = U_new
    # labels stay aligned with U's columns (NOT canonicalised) so soft
    # membership merging can consume U directly.
    return np.argmax(U, axis=1).astype(np.int64), U


# ---------------------------------------------------------------------------
# Registry entries — the uniform (labels, membership | None) signature
# ---------------------------------------------------------------------------


@register_clustering("hc")
def _hc(feats, r, *, linkage="average", seed=0):
    return hierarchical_cluster(feats, r, linkage), None


@register_clustering("kmeans_fix")
def _kmeans_fix(feats, r, *, linkage="average", seed=0):
    return kmeans_cluster(feats, r, "fix", seed), None


@register_clustering("kmeans_rnd")
def _kmeans_rnd(feats, r, *, linkage="average", seed=0):
    return kmeans_cluster(feats, r, "rnd", seed), None


@register_clustering("fcm")
def _fcm(feats, r, *, linkage="average", seed=0):
    return fcm_cluster(feats, r, seed=seed)


# fcm's soft membership becomes the combine matrix directly; that path is
# applied by the numpy plan executor, not the jax einsum executor.
_fcm.jax_executor = False


def cluster(feats: np.ndarray, r: int, method: str = "hc",
            linkage: str = "average", seed: int = 0) -> np.ndarray:
    """Labels-only convenience wrapper over the clustering registry."""
    return CLUSTERINGS.get(method)(feats, r, linkage=linkage, seed=seed)[0]
