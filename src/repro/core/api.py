"""Public accessors for MoE param/pattern layout.

These used to live as private helpers (``pipeline._layer_weights``,
``pipeline._moe_positions``) that baselines, quality benchmarks, and tests
reached into. They are the supported surface for any code that needs to
address individual expert stacks inside a params pytree.

Params layout reminder: every MoE pattern position ``pos`` holds STACKED
blocks — ``params["decoder"]["blocks"][f"layer{pos}"]["moe"]["wg"]`` has
shape ``(n_blocks, E, d, f)`` — so a single (pattern_pos, block) pair
addresses one concrete MoE layer.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def moe_positions(cfg) -> List[int]:
    """Pattern positions whose FFN is an MoE (in pattern order)."""
    return [i for i, s in enumerate(cfg.pattern) if s.ffn == "moe"]


def moe_params(params, pos: int) -> dict:
    """The stacked MoE param dict at pattern position ``pos``."""
    return params["decoder"]["blocks"][f"layer{pos}"]["moe"]


def layer_weights(params, pos: int, block: int) -> Tuple[np.ndarray, ...]:
    """One MoE layer's expert weights as float32 numpy:
    ``(wg, wu, wd)`` with shapes ``(E, d, f)``, ``(E, d, f)``, ``(E, f, d)``.
    """
    moe = moe_params(params, pos)
    return (np.asarray(moe["wg"][block], np.float32),
            np.asarray(moe["wu"][block], np.float32),
            np.asarray(moe["wd"][block], np.float32))
