"""Expert similarity feature builders (paper §3.2.1, Table 4).

Given per-layer calibration stats + expert weights, build the (E, D) feature
matrix each metric clusters on:

  expert_output — o_j = mean over calib tokens of E_j(x)     (Eq. 4; O(d))
  router_logits — expert j's router logit trace on sampled tokens (M-SMoE)
  weight        — flattened [W_gate | W_up | W_down^T]        (O(3 d d_ff))

Every metric is registered in :data:`repro.core.registry.METRICS` under the
uniform signature ``fn(stats, weights) -> (E, D)``; add new similarity
metrics with ``@register_metric("name")`` and they become valid
``HCSMoEConfig.metric`` / ``PlanSpec.metric`` values automatically.
"""
from __future__ import annotations

import numpy as np

from repro.core.registry import METRICS, register_metric


@register_metric("expert_output")
def expert_output_features(stats, weights=None) -> np.ndarray:
    out_sum = np.asarray(stats.out_sum, np.float64)  # (E, d)
    count = float(np.asarray(stats.token_count))
    return out_sum / max(count, 1.0)


@register_metric("router_logits")
def router_logit_features(stats, weights=None) -> np.ndarray:
    return np.asarray(stats.logits_sample, np.float64).T  # (E, T_sub)


@register_metric("weight")
def weight_features(stats, weights) -> np.ndarray:
    wg, wu, wd = weights
    E = wg.shape[0]
    parts = [np.asarray(w, np.float64).reshape(E, -1) for w in (wg, wu, wd)]
    return np.concatenate(parts, axis=1)


def build_features(metric: str, stats=None, weights=None) -> np.ndarray:
    return METRICS.get(metric)(stats, weights)
