"""Expert similarity feature builders (paper §3.2.1, Table 4).

Given per-layer calibration stats + expert weights, build the (E, D) feature
matrix each metric clusters on:

  expert_output — o_j = mean over calib tokens of E_j(x)     (Eq. 4; O(d))
  router_logits — expert j's router logit trace on sampled tokens (M-SMoE)
  weight        — flattened [W_gate | W_up | W_down^T]        (O(3 d d_ff))
"""
from __future__ import annotations

import numpy as np

METRICS = ("expert_output", "router_logits", "weight")


def expert_output_features(stats) -> np.ndarray:
    out_sum = np.asarray(stats.out_sum, np.float64)  # (E, d)
    count = float(np.asarray(stats.token_count))
    return out_sum / max(count, 1.0)


def router_logit_features(stats) -> np.ndarray:
    return np.asarray(stats.logits_sample, np.float64).T  # (E, T_sub)


def weight_features(wg, wu, wd) -> np.ndarray:
    E = wg.shape[0]
    parts = [np.asarray(w, np.float64).reshape(E, -1) for w in (wg, wu, wd)]
    return np.concatenate(parts, axis=1)


def build_features(metric: str, stats=None, weights=None) -> np.ndarray:
    if metric == "expert_output":
        return expert_output_features(stats)
    if metric == "router_logits":
        return router_logit_features(stats)
    if metric == "weight":
        return weight_features(*weights)
    raise ValueError(metric)
