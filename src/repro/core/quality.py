"""Cluster-quality and model-fidelity metrics (paper Appendix D, Table 23).

  * L2 error / cosine similarity of final hidden states vs the original model
  * Silhouette score (euclidean & cosine)
  * Dunn index (euclidean & cosine)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import pairwise_euclidean


def _pairwise_cosine_dist(feats: np.ndarray) -> np.ndarray:
    f = np.asarray(feats, np.float64)
    f = f / np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    return 1.0 - f @ f.T


def silhouette_score(feats: np.ndarray, labels: np.ndarray,
                     metric: str = "euclidean") -> float:
    D = (pairwise_euclidean(feats) if metric == "euclidean"
         else _pairwise_cosine_dist(feats))
    n = len(labels)
    uniq = np.unique(labels)
    if len(uniq) < 2:
        return 0.0
    s_vals = []
    for i in range(n):
        same = (labels == labels[i])
        n_same = same.sum() - 1
        if n_same == 0:
            s_vals.append(0.0)
            continue
        a = D[i][same].sum() / n_same
        b = min(D[i][labels == c].mean() for c in uniq if c != labels[i])
        s_vals.append((b - a) / max(a, b, 1e-12))
    return float(np.mean(s_vals))


def dunn_index(feats: np.ndarray, labels: np.ndarray,
               metric: str = "euclidean") -> float:
    D = (pairwise_euclidean(feats) if metric == "euclidean"
         else _pairwise_cosine_dist(feats))
    np.fill_diagonal(D, 0.0)
    uniq = np.unique(labels)
    if len(uniq) < 2:
        return 0.0
    max_intra = 0.0
    for c in uniq:
        idx = np.where(labels == c)[0]
        if len(idx) > 1:
            max_intra = max(max_intra, D[np.ix_(idx, idx)].max())
    min_inter = np.inf
    for i, c1 in enumerate(uniq):
        for c2 in uniq[i + 1:]:
            i1, i2 = np.where(labels == c1)[0], np.where(labels == c2)[0]
            min_inter = min(min_inter, D[np.ix_(i1, i2)].min())
    return float(min_inter / max(max_intra, 1e-12))


def cluster_quality_report(feats: np.ndarray, labels: np.ndarray) -> dict:
    return {
        "silhouette_euc": silhouette_score(feats, labels, "euclidean"),
        "silhouette_cos": silhouette_score(feats, labels, "cosine"),
        "dunn_euc": dunn_index(feats, labels, "euclidean"),
        "dunn_cos": dunn_index(feats, labels, "cosine"),
    }


# ---------------------------------------------------------------------------
# Model output fidelity (Table 23 L2 / cosine columns)
# ---------------------------------------------------------------------------


_JIT_CACHE = {}


def _cached_jit(kind, model, moe_mode, make):
    key = (kind, id(model), moe_mode)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = make()
    return _JIT_CACHE[key]


def output_fidelity(model, params_orig, params_merged, batches,
                    *, moe_mode: str = "dense") -> dict:
    """Compare final logits on eval batches: L2 error + cosine similarity."""

    vocab = model.cfg.vocab_size

    def make():
        @jax.jit
        def logits_of(params, batch):
            kwargs = {k: v for k, v in batch.items() if k != "labels"}
            out, _ = model.forward(params, **kwargs, moe_mode=moe_mode)
            # drop padded-vocab ids (masked to -1e30 — they would NaN the
            # cosine) and compare live logits only
            return out[..., :vocab].astype(jnp.float32)

        return logits_of

    logits_of = _cached_jit("fidelity", model, moe_mode, make)

    l2, cos, n = 0.0, 0.0, 0
    for batch in batches:
        a = logits_of(params_orig, batch)
        b = logits_of(params_merged, batch)
        l2 += float(jnp.sqrt(jnp.sum((a - b) ** 2)))
        an = a.reshape(-1)
        bn = b.reshape(-1)
        cos += float(jnp.vdot(an, bn) /
                     jnp.maximum(jnp.linalg.norm(an) * jnp.linalg.norm(bn), 1e-9))
        n += 1
    return {"l2_error": l2 / n, "cosine_similarity": cos / n}


def eval_loss(model, params, batches, *, moe_mode: str = "ragged") -> float:
    """Mean eval CE loss (the quality score for Tables 2/3 analogs)."""

    def make():
        @jax.jit
        def step(params, batch):
            loss, _ = model.train_loss(params, batch, moe_mode=moe_mode,
                                       remat="none", lb_coef=0.0, z_coef=0.0)
            return loss

        return step

    step = _cached_jit("eval_loss", model, moe_mode, make)
    vals = [float(step(params, b)) for b in batches]
    return float(np.mean(vals))
