"""Expert weight-space merging (paper §3.2.3, Appendix B.2).

Given cluster labels for one MoE layer and the stacked expert weights
(wg, wu: (E, d, f); wd: (E, f, d)), produce merged weights with ``r`` live
slots. Methods:

  average   — alpha_j = 1/|C|
  frequency — alpha_j = freq_j / sum_cluster freq           (Alg. 1 line 16)
  fix_dom   — ZipIt adaptation: permute each non-dominant expert's hidden
              features onto the dominant expert's feature order via
              correlation argmax, then weighted-average (Fig. 4)
  zipit     — full ZipIt-style greedy pairwise feature matching within the
              cluster (reference implementation; orders of magnitude slower,
              Table 9)

Every method is registered in :data:`repro.core.registry.MERGES` as a PLAN
producer: from (labels, freq, weights, calibration samples) it emits a
serializable per-layer merge description — either

  * ``combine`` — an ``(r, E)`` convex-combination matrix (frequency /
    average / FCM soft membership), applied as a single einsum over the
    stacked expert weights (:func:`merge_stacked_jax`, EP/TP-shardable), or
  * ``hidden_map`` — an ``(E, f)`` int map routing every expert's hidden
    feature dim onto a feature dim of its merged slot (fix_dom / zipit,
    whose feature matching is not an expert-level linear combination),
    applied by the count-normalised column/row scatter
    :func:`apply_hidden_map_np`.

Both descriptions are pure data: applying one needs ONLY the original
weights, which is what makes :class:`repro.core.plan.MergePlan` an offline,
on-disk artifact. ``@register_merge("name")`` plugs in a new method.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.registry import MERGES, register_merge

FIX_DOM_FEATURES = ("act", "weight", "act+weight")


def cluster_alphas(labels: np.ndarray, freq: np.ndarray, method: str):
    """Per-expert merge coefficient alpha_j (normalised within cluster)."""
    # this module IS the implementation the registry names point at, so the
    # two alpha formulas are selected by literal here
    if method not in ("average", "frequency"):  # noqa: RPR006
        raise ValueError(
            f"cluster_alphas supports 'average'/'frequency', got {method!r}")
    E = labels.shape[0]
    alphas = np.zeros(E, np.float64)
    for c in np.unique(labels):
        members = np.where(labels == c)[0]
        if method == "average":  # noqa: RPR006  (see note above)
            alphas[members] = 1.0 / len(members)
        else:
            fsum = float(freq[members].sum())
            if fsum <= 0:
                alphas[members] = 1.0 / len(members)
            else:
                alphas[members] = freq[members] / fsum
    return alphas


def build_combine_matrix(labels: np.ndarray, freq: np.ndarray, method: str,
                         num_slots: int) -> np.ndarray:
    """(num_slots, E) convex combination matrix from labels + frequencies."""
    alphas = cluster_alphas(labels, freq, method)
    E = labels.shape[0]
    M = np.zeros((num_slots, E), np.float32)
    M[labels, np.arange(E)] = alphas
    return M


# ---------------------------------------------------------------------------
# Executors — how a merge description turns weights into merged weights
# ---------------------------------------------------------------------------


def merge_stacked_jax(wg, wu, wd, combine):
    """Sharded merge: combine (L, r, E) convex weights; w* (L, E, d, f).

    A single einsum per tensor, so under pjit each TP/FSDP/EP shard merges
    its slice locally with zero resharding (DESIGN.md §3)."""
    c = combine.astype(jnp.float32)
    mg = jnp.einsum("lre,ledf->lrdf", c, wg.astype(jnp.float32))
    mu = jnp.einsum("lre,ledf->lrdf", c, wu.astype(jnp.float32))
    md = jnp.einsum("lre,lefd->lrfd", c, wd.astype(jnp.float32))
    return mg.astype(wg.dtype), mu.astype(wu.dtype), md.astype(wd.dtype)


def apply_combine_np(wg, wu, wd, combine):
    """Numpy reference of the combine executor (float64 accumulation).

    Row ``c`` of ``combine`` weights every original expert; rows past the
    layer's live slot count are all-zero and produce zero (dead) slots."""
    combine = np.asarray(combine, np.float64)
    out_g = np.stack([(c[:, None, None] * wg).sum(0) for c in combine])
    out_u = np.stack([(c[:, None, None] * wu).sum(0) for c in combine])
    out_d = np.stack([(c[:, None, None] * wd).sum(0) for c in combine])
    return out_g, out_u, out_d


def apply_hidden_map_np(wg, wu, wd, labels, hidden_map, num_slots: int):
    """Count-normalised feature scatter: expert ``e``'s hidden dim ``j``
    lands on dim ``hidden_map[e, j]`` of slot ``labels[e]``; every target
    dim is divided by the number of contributions it received. This is the
    exact algebra of both fix-dom (dominant maps identity, so each target
    column averages dominant + matched columns) and zipit (each feature
    group averages its member columns). Deterministic: ``np.add.at``
    accumulates in (expert asc, feature asc) order."""
    E, d, f = wg.shape
    labels = np.asarray(labels, np.int64)
    hm = np.asarray(hidden_map, np.int64)
    idx = (labels[:, None] * f + hm).reshape(-1)          # (E*f,)
    counts = np.bincount(idx, minlength=num_slots * f).astype(np.float64)
    denom = np.maximum(counts, 1.0)[:, None]

    def cols(w):  # scatter feature COLUMNS of (E, d, f)
        acc = np.zeros((num_slots * f, d))
        np.add.at(acc, idx, w.transpose(0, 2, 1).reshape(E * f, d))
        return (acc / denom).reshape(num_slots, f, d).transpose(0, 2, 1)

    def rows(w):  # scatter feature ROWS of (E, f, d)
        acc = np.zeros((num_slots * f, d))
        np.add.at(acc, idx, w.reshape(E * f, d))
        return (acc / denom).reshape(num_slots, f, d)

    return cols(wg), cols(wu), rows(wd)


# ---------------------------------------------------------------------------
# Registered merge-plan producers
# ---------------------------------------------------------------------------


@dataclass
class MergeInputs:
    """Everything a merge method may consult when planning one layer."""
    labels: np.ndarray            # (E,) cluster assignment
    freq: np.ndarray              # (E,) activation frequencies
    wg: np.ndarray                # (E, d, f) float64
    wu: np.ndarray                # (E, d, f) float64
    wd: np.ndarray                # (E, f, d) float64
    num_slots: int                # rows of the emitted combine matrix
    act_sample: Optional[np.ndarray] = None   # (E, T, f) calib activations
    feature: str = "act"          # fix-dom feature source


@register_merge("frequency")
def _plan_frequency(mi: MergeInputs) -> dict:
    return {"combine": build_combine_matrix(mi.labels, mi.freq, "frequency",
                                            mi.num_slots)}


@register_merge("average")
def _plan_average(mi: MergeInputs) -> dict:
    return {"combine": build_combine_matrix(mi.labels, mi.freq, "average",
                                            mi.num_slots)}


# combine-only merges are expressible as einsums over stacked weights, so
# the jax plan executor can apply them; feature-matching merges (fix_dom,
# zipit) emit per-expert hidden_maps and stay on the numpy executor.
_plan_frequency.jax_executor = True
_plan_average.jax_executor = True


def _correlation_map(feat_dom: np.ndarray, feat_e: np.ndarray) -> np.ndarray:
    """For each feature dim of expert e, index of the most-correlated
    dominant feature dim. feats: (T, f) activation traces (or (3d, f))."""
    a = feat_dom - feat_dom.mean(0, keepdims=True)
    b = feat_e - feat_e.mean(0, keepdims=True)
    a /= np.maximum(np.linalg.norm(a, axis=0, keepdims=True), 1e-9)
    b /= np.maximum(np.linalg.norm(b, axis=0, keepdims=True), 1e-9)
    corr = b.T @ a  # (f_e, f_dom)
    return np.argmax(corr, axis=1)


def _fix_dom_features(feature: str, act_sample, wg, wu, wd, e: int):
    if feature == "act":
        return np.asarray(act_sample[e], np.float64)  # (T, f)
    # fix-dom feature *source* name, which collides with the metric "weight"
    if feature == "weight":  # noqa: RPR006
        return np.concatenate(
            [np.asarray(wg[e], np.float64), np.asarray(wu[e], np.float64),
             np.asarray(wd[e], np.float64).T], axis=0)  # (3d, f)
    if feature == "act+weight":
        return np.concatenate(
            [_fix_dom_features("act", act_sample, wg, wu, wd, e),
             _fix_dom_features("weight", act_sample, wg, wu, wd, e)], axis=0)
    raise ValueError(
        f"unknown fix_dom feature {feature!r}; valid: {FIX_DOM_FEATURES}")


@register_merge("fix_dom")
def _plan_fix_dom(mi: MergeInputs) -> dict:
    """Dominant expert keeps its feature order (identity map); every other
    member's dims are routed onto their most-correlated dominant dims."""
    E, d, f = mi.wg.shape
    hidden_map = np.tile(np.arange(f, dtype=np.int32), (E, 1))
    for c in np.unique(mi.labels):
        members = np.where(mi.labels == c)[0]
        dom = members[int(np.argmax(mi.freq[members]))]
        feat_dom = _fix_dom_features(mi.feature, mi.act_sample,
                                     mi.wg, mi.wu, mi.wd, dom)
        for e in members:
            if e == dom:
                continue
            hidden_map[e] = _correlation_map(
                feat_dom, _fix_dom_features(mi.feature, mi.act_sample,
                                            mi.wg, mi.wu, mi.wd, e))
    return {"hidden_map": hidden_map}


_plan_fix_dom.needs_act_sample = True


@register_merge("zipit")
def _plan_zipit(mi: MergeInputs) -> dict:
    """Greedy pairwise feature matching: concatenate the cluster's feature
    columns, merge the most-correlated pair until f dims remain, and map
    every original column to its surviving group index."""
    E, d, f = mi.wg.shape
    hidden_map = np.tile(np.arange(f, dtype=np.int32), (E, 1))
    for c in np.unique(mi.labels):
        members = np.where(mi.labels == c)[0]
        if len(members) == 1:
            continue  # identity map: the expert survives unchanged
        feats = np.concatenate(
            [_fix_dom_features(mi.feature, mi.act_sample,
                               mi.wg, mi.wu, mi.wd, e)
             for e in members], axis=1)  # (T, f*|C|)
        for out_i, group in enumerate(_zipit_groups(feats, f)):
            for col in group:
                m, j = divmod(col, f)
                hidden_map[members[m], j] = out_i
    return {"hidden_map": hidden_map}


_plan_zipit.needs_act_sample = True


def _zipit_groups(feats, target_f: int):
    """Greedy pairwise feature merging until ``target_f`` groups remain.
    Returns the surviving groups (lists of concatenated column indices) in
    alive order — group ``i`` becomes output feature dim ``i``."""
    a = feats - feats.mean(0, keepdims=True)
    a = a / np.maximum(np.linalg.norm(a, axis=0, keepdims=True), 1e-9)
    corr = a.T @ a
    np.fill_diagonal(corr, -np.inf)
    groups = [[i] for i in range(feats.shape[1])]
    alive = list(range(feats.shape[1]))
    while len(alive) > target_f:
        sub = corr[np.ix_(alive, alive)]
        ai, aj = divmod(int(np.argmax(sub)), len(alive))
        i, j = alive[ai], alive[aj]
        if i > j:
            i, j = j, i
        groups[i].extend(groups[j])
        # merged correlation = average of rows
        corr[i, :] = (corr[i, :] + corr[j, :]) / 2.0
        corr[:, i] = corr[i, :]
        corr[i, i] = -np.inf
        corr[j, :] = corr[:, j] = -np.inf
        alive.remove(j)
    return [groups[i] for i in alive]


# ---------------------------------------------------------------------------
# Single-layer reference entry point (numpy, all methods)
# ---------------------------------------------------------------------------


def merge_layer(wg, wu, wd, labels: np.ndarray, freq: np.ndarray,
                method: str = "frequency", act_sample=None,
                feature: str = "act", membership: np.ndarray | None = None):
    """Returns (wg', wu', wd', group_map) with r live expert slots.

    membership (E, r): soft FCM merging weights (Appendix B.5 Eq. 15);
    overrides labels-based merging when provided. Plans one layer through
    the merge registry and applies it with the shared numpy executors.
    """
    wg = np.asarray(wg, np.float64)
    wu = np.asarray(wu, np.float64)
    wd = np.asarray(wd, np.float64)
    labels = np.asarray(labels)

    if membership is not None:  # soft (FCM) merging: U^T IS the combine
        combine = np.asarray(membership, np.float64).T  # (r, E)
        out_g, out_u, out_d = apply_combine_np(wg, wu, wd, combine)
        return out_g, out_u, out_d, labels.astype(np.int32)

    r = int(labels.max()) + 1
    payload = MERGES.get(method)(MergeInputs(
        labels=labels, freq=np.asarray(freq, np.float64),
        wg=wg, wu=wu, wd=wd, num_slots=r,
        act_sample=act_sample, feature=feature))
    if "combine" in payload:
        out_g, out_u, out_d = apply_combine_np(wg, wu, wd,
                                               payload["combine"])
    else:
        out_g, out_u, out_d = apply_hidden_map_np(
            wg, wu, wd, labels, payload["hidden_map"], r)
    return out_g, out_u, out_d, labels.astype(np.int32)
