"""Expert weight-space merging (paper §3.2.3, Appendix B.2).

Given cluster labels for one MoE layer and the stacked expert weights
(wg, wu: (E, d, f); wd: (E, f, d)), produce merged weights with ``r`` live
slots. Methods:

  average   — alpha_j = 1/|C|
  frequency — alpha_j = freq_j / sum_cluster freq           (Alg. 1 line 16)
  fix_dom   — ZipIt adaptation: permute each non-dominant expert's hidden
              features onto the dominant expert's feature order via
              correlation argmax, then weighted-average (Fig. 4)
  zipit     — full ZipIt-style greedy pairwise feature matching within the
              cluster (reference implementation; orders of magnitude slower,
              Table 9)
"""
from __future__ import annotations

import numpy as np


def cluster_alphas(labels: np.ndarray, freq: np.ndarray, method: str):
    """Per-expert merge coefficient alpha_j (normalised within cluster)."""
    E = labels.shape[0]
    alphas = np.zeros(E, np.float64)
    for c in np.unique(labels):
        members = np.where(labels == c)[0]
        if method == "average":
            alphas[members] = 1.0 / len(members)
        elif method == "frequency":
            fsum = float(freq[members].sum())
            if fsum <= 0:
                alphas[members] = 1.0 / len(members)
            else:
                alphas[members] = freq[members] / fsum
        else:
            raise ValueError(method)
    return alphas


def _correlation_map(feat_dom: np.ndarray, feat_e: np.ndarray) -> np.ndarray:
    """For each feature dim of expert e, index of the most-correlated
    dominant feature dim. feats: (T, f) activation traces (or (3d, f))."""
    a = feat_dom - feat_dom.mean(0, keepdims=True)
    b = feat_e - feat_e.mean(0, keepdims=True)
    a /= np.maximum(np.linalg.norm(a, axis=0, keepdims=True), 1e-9)
    b /= np.maximum(np.linalg.norm(b, axis=0, keepdims=True), 1e-9)
    corr = b.T @ a  # (f_e, f_dom)
    return np.argmax(corr, axis=1)


def _fix_dom_features(feature: str, act_sample, wg, wu, wd, e: int):
    if feature == "act":
        return np.asarray(act_sample[e], np.float64)  # (T, f)
    if feature == "weight":
        return np.concatenate(
            [np.asarray(wg[e], np.float64), np.asarray(wu[e], np.float64),
             np.asarray(wd[e], np.float64).T], axis=0)  # (3d, f)
    if feature == "act+weight":
        return np.concatenate(
            [_fix_dom_features("act", act_sample, wg, wu, wd, e),
             _fix_dom_features("weight", act_sample, wg, wu, wd, e)], axis=0)
    raise ValueError(feature)


def merge_layer(wg, wu, wd, labels: np.ndarray, freq: np.ndarray,
                method: str = "frequency", act_sample=None,
                feature: str = "act", membership: np.ndarray | None = None):
    """Returns (wg', wu', wd', group_map) with r live expert slots.

    membership (E, r): soft FCM merging weights (Appendix B.5 Eq. 15);
    overrides labels-based alphas when provided.
    """
    wg = np.asarray(wg, np.float64)
    wu = np.asarray(wu, np.float64)
    wd = np.asarray(wd, np.float64)
    E, d, f = wg.shape
    labels = np.asarray(labels)
    r = membership.shape[1] if membership is not None else int(labels.max()) + 1

    out_g = np.zeros((r, d, f))
    out_u = np.zeros((r, d, f))
    out_d = np.zeros((r, f, d))

    if membership is not None:  # soft (FCM) merging
        for c in range(r):
            w = membership[:, c][:, None, None]
            out_g[c] = (w * wg).sum(0)
            out_u[c] = (w * wu).sum(0)
            out_d[c] = (w * wd).sum(0)
        return out_g, out_u, out_d, labels.astype(np.int32)

    if method in ("average", "frequency"):
        alphas = cluster_alphas(labels, freq, method)
        for e in range(E):
            c = labels[e]
            out_g[c] += alphas[e] * wg[e]
            out_u[c] += alphas[e] * wu[e]
            out_d[c] += alphas[e] * wd[e]
    elif method == "fix_dom":
        alphas = cluster_alphas(labels, freq, "average")
        for c in range(r):
            members = np.where(labels == c)[0]
            dom = members[int(np.argmax(freq[members]))]
            feat_dom = _fix_dom_features(feature, act_sample, wg, wu, wd, dom)
            acc_g = wg[dom].copy()
            acc_u = wu[dom].copy()
            acc_d = wd[dom].copy()
            counts = np.ones(f)
            for e in members:
                if e == dom:
                    continue
                fmap = _correlation_map(feat_dom,
                                        _fix_dom_features(feature, act_sample,
                                                          wg, wu, wd, e))
                # accumulate expert e's hidden dim j onto dominant dim fmap[j]
                for j in range(f):
                    m = fmap[j]
                    acc_g[:, m] += wg[e][:, j]
                    acc_u[:, m] += wu[e][:, j]
                    acc_d[m, :] += wd[e][j, :]
                    counts[m] += 1
            out_g[c] = acc_g / counts[None, :]
            out_u[c] = acc_u / counts[None, :]
            out_d[c] = acc_d / counts[:, None]
    elif method == "zipit":
        # Reference ZipIt within cluster: greedily merge the most correlated
        # feature pairs of the concatenated experts down to f dims.
        for c in range(int(labels.max()) + 1):
            members = np.where(labels == c)[0]
            if len(members) == 1:
                e = members[0]
                out_g[c], out_u[c], out_d[c] = wg[e], wu[e], wd[e]
                continue
            feats = np.concatenate(
                [_fix_dom_features(feature, act_sample, wg, wu, wd, e)
                 for e in members], axis=1)  # (T, f*|C|)
            G = np.concatenate([wg[e] for e in members], axis=1)
            U = np.concatenate([wu[e] for e in members], axis=1)
            Dn = np.concatenate([wd[e] for e in members], axis=0)
            out_g[c], out_u[c], out_d[c] = _zipit_reduce(feats, G, U, Dn, f)
    else:
        raise ValueError(method)

    dtype = np.asarray(wg).dtype
    return (out_g.astype(dtype), out_u.astype(dtype), out_d.astype(dtype),
            labels.astype(np.int32))


def _zipit_reduce(feats, G, U, Dn, target_f: int):
    """Greedy pairwise feature merging until target_f dims remain."""
    a = feats - feats.mean(0, keepdims=True)
    a = a / np.maximum(np.linalg.norm(a, axis=0, keepdims=True), 1e-9)
    corr = a.T @ a
    np.fill_diagonal(corr, -np.inf)
    groups = [[i] for i in range(feats.shape[1])]
    alive = list(range(feats.shape[1]))
    while len(alive) > target_f:
        sub = corr[np.ix_(alive, alive)]
        ai, aj = divmod(int(np.argmax(sub)), len(alive))
        i, j = alive[ai], alive[aj]
        if i > j:
            i, j = j, i
        groups[i].extend(groups[j])
        # merged correlation = average of rows
        corr[i, :] = (corr[i, :] + corr[j, :]) / 2.0
        corr[:, i] = corr[i, :]
        corr[i, i] = -np.inf
        corr[j, :] = corr[:, j] = -np.inf
        alive.remove(j)
    out_g = np.stack([G[:, groups[i]].mean(1) for i in alive], axis=1)
    out_u = np.stack([U[:, groups[i]].mean(1) for i in alive], axis=1)
    out_d = np.stack([Dn[groups[i], :].mean(0) for i in alive], axis=0)
    return out_g, out_u, out_d
