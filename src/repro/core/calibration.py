"""Calibration pass (paper Alg. 1 lines 1-4): run the original model over a
calibration dataset and accumulate per-MoE-layer statistics — mean expert
outputs (Eq. 4), router-logit samples, activation frequencies, intermediate
activation samples — via the model's ``capture_stats`` path.

Stats come back stacked like the scanned params: a tuple over pattern
positions, each an :class:`MoEStats` with a leading ``n_blocks`` dim.
"""
from __future__ import annotations

from functools import partial
from typing import List

import jax


def _accumulate(acc, new):
    """Streaming combine of two stats pytrees: sums add, samples keep first."""
    if acc is None:
        return new

    def comb(path_leafname, a, b):
        return a + b

    def combine_stats(a, b):
        return type(a)(
            out_sum=a.out_sum + b.out_sum,
            token_count=a.token_count + b.token_count,
            freq=a.freq + b.freq,
            logits_sample=a.logits_sample,   # first-batch sample
            act_sample=a.act_sample,
            x_sample=a.x_sample,
        )

    return jax.tree.map(combine_stats, acc, new,
                        is_leaf=lambda x: hasattr(x, "out_sum"))


def _check_unmerged(params):
    """Calibration stats are defined over the ORIGINAL expert set; merged
    params (non-identity group_map, possibly padded back to E slots) would
    silently attribute merged-slot outputs to original expert ids. The slot
    count is checked statically inside ``moe_forward``; this catches the
    padded case (resize=False keeps E slots) by value, outside jit."""
    import numpy as np

    def visit(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        if keys and keys[-1] == "group_map":
            gm = np.asarray(leaf)
            ident = np.arange(gm.shape[-1], dtype=gm.dtype)
            if not np.array_equal(gm, np.broadcast_to(ident, gm.shape)):
                raise ValueError(
                    "collect_moe_stats: params carry a non-identity "
                    "group_map (merged experts). Calibrate on the original "
                    "params, before apply_hcsmoe.")
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)


def collect_moe_stats(model, params, batches, *, moe_mode: str = "dense"):
    """batches: iterable of input dicts. Returns stacked stats pytree.

    Uses the dense MoE path because Eq. 4 requires every expert's output on
    every calibration token regardless of routing. Raises if ``params`` have
    already been merged (stats are pre-merge-only).
    """
    _check_unmerged(params)

    @partial(jax.jit, static_argnames=("moe_mode",))
    def step(params, batch, moe_mode="dense"):
        kwargs = {k: v for k, v in batch.items() if k != "labels"}
        _, aux = model.forward(params, **kwargs, moe_mode=moe_mode,
                               capture_stats=True)
        return aux["stats"]

    acc = None
    for batch in batches:
        acc = _accumulate(acc, step(params, batch, moe_mode=moe_mode))
    return acc


def flatten_stats(cfg, stats) -> List[dict]:
    """Stacked stats -> per-layer list ordered by global layer index.

    Each entry: {"pattern_pos", "block", "stats": MoEStats (unstacked)}.
    """
    moe_positions = [i for i, s in enumerate(cfg.pattern) if s.ffn == "moe"]
    out = []
    for b in range(cfg.num_blocks):
        for slot, pos in enumerate(moe_positions):
            st = jax.tree.map(lambda x, b=b: x[b], stats[slot])
            out.append({"pattern_pos": pos, "block": b, "stats": st})
    return out
