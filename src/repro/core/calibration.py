"""Calibration pass (paper Alg. 1 lines 1-4): run the original model over a
calibration dataset and accumulate per-MoE-layer statistics — mean expert
outputs (Eq. 4), router-logit samples, activation frequencies, intermediate
activation samples — via the model's ``capture_stats`` path.

Stats come back stacked like the scanned params: a tuple over pattern
positions, each an :class:`MoEStats` with a leading ``n_blocks`` dim.
"""
from __future__ import annotations

from functools import partial
from typing import List

import jax
import jax.numpy as jnp


def _accumulate(acc, new):
    """Streaming combine of two stats pytrees: sums add, samples keep first."""
    if acc is None:
        return new

    def comb(path_leafname, a, b):
        return a + b

    def combine_stats(a, b):
        return type(a)(
            out_sum=a.out_sum + b.out_sum,
            token_count=a.token_count + b.token_count,
            freq=a.freq + b.freq,
            logits_sample=a.logits_sample,   # first-batch sample
            act_sample=a.act_sample,
            x_sample=a.x_sample,
        )

    return jax.tree.map(combine_stats, acc, new,
                        is_leaf=lambda x: hasattr(x, "out_sum"))


def collect_moe_stats(model, params, batches, *, moe_mode: str = "dense"):
    """batches: iterable of input dicts. Returns stacked stats pytree.

    Uses the dense MoE path because Eq. 4 requires every expert's output on
    every calibration token regardless of routing.
    """

    @partial(jax.jit, static_argnames=("moe_mode",))
    def step(params, batch, moe_mode="dense"):
        kwargs = {k: v for k, v in batch.items() if k != "labels"}
        _, aux = model.forward(params, **kwargs, moe_mode=moe_mode,
                               capture_stats=True)
        return aux["stats"]

    acc = None
    for batch in batches:
        acc = _accumulate(acc, step(params, batch, moe_mode=moe_mode))
    return acc


def flatten_stats(cfg, stats) -> List[dict]:
    """Stacked stats -> per-layer list ordered by global layer index.

    Each entry: {"pattern_pos", "block", "stats": MoEStats (unstacked)}.
    """
    moe_positions = [i for i, s in enumerate(cfg.pattern) if s.ffn == "moe"]
    out = []
    for b in range(cfg.num_blocks):
        for slot, pos in enumerate(moe_positions):
            st = jax.tree.map(lambda x: x[b], stats[slot])
            out.append({"pattern_pos": pos, "block": b, "stats": st})
    return out
