"""Retraining-free baselines reproduced from the paper (§4.1, Table 1).

  F-prune — global frequency-ranked expert pruning (dynamic per layer)
  S-prune — global router-score-ranked pruning (He et al., 2024)
  O-prune — per-layer subset search minimising layer-output deviation
            (Lu et al., 2024), with sampled search like the paper's 10^5 run
  M-SMoE  — frequency-dominant selection + router-logit one-shot grouping +
            frequency merging (Li et al., 2024), task-agnostic setting
  one_shot_grouping — Table 6's single-pass grouping under any metric

Pruning writes ``router_mask`` (-1e9) so routing renormalises over kept
experts; weights of pruned experts are zeroed (ragged path then assigns them
zero tokens and zero FLOPs). Merging baselines reuse the merge machinery.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as met
from repro.core.calibration import flatten_stats
from repro.core.pipeline import _layer_weights, _moe_positions

NEG = -1.0e9


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _global_scores_keep(layers, scores: np.ndarray, keep_total: int):
    """Global top-k across (L, E) scores -> per-layer keep masks (dynamic)."""
    L, E = scores.shape
    order = np.argsort(-scores.reshape(-1), kind="stable")
    keep = np.zeros(L * E, bool)
    keep[order[:keep_total]] = True
    keep = keep.reshape(L, E)
    # every layer keeps at least one expert
    for l in range(L):
        if not keep[l].any():
            keep[l, int(np.argmax(scores[l]))] = True
    return keep


def _apply_prune(cfg, params, keep_masks: List[np.ndarray], layers):
    new_params = jax.tree.map(lambda x: x, params)
    positions = _moe_positions(cfg)
    by_pos = {p: [] for p in positions}
    for layer, keep in zip(layers, keep_masks):
        by_pos[layer["pattern_pos"]].append((layer["block"], keep))
    for pos in positions:
        entries = sorted(by_pos[pos])
        mask = np.stack([k for _, k in entries])  # (n_blocks, E)
        moe = new_params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        rmask = jnp.where(jnp.asarray(mask), 0.0, NEG).astype(jnp.float32)
        moe["router_mask"] = rmask
        m = jnp.asarray(mask)[:, :, None, None]
        moe["wg"] = jnp.where(m, moe["wg"], 0)
        moe["wu"] = jnp.where(m, moe["wu"], 0)
        moe["wd"] = jnp.where(m, moe["wd"], 0)
    return new_params


# ---------------------------------------------------------------------------
# F-prune / S-prune
# ---------------------------------------------------------------------------


def f_prune(cfg, params, stats, r: int):
    layers = flatten_stats(cfg, stats)
    scores = np.stack([np.asarray(l["stats"].freq, np.float64) for l in layers])
    keep = _global_scores_keep(layers, scores, r * len(layers))
    return _apply_prune(cfg, params, list(keep), layers), {"keep": keep}


def s_prune(cfg, params, stats, r: int):
    """Router-score pruning: accumulate softmax router probs per expert."""
    layers = flatten_stats(cfg, stats)
    scores = []
    for l in layers:
        logits = np.asarray(l["stats"].logits_sample, np.float64)  # (T, E)
        probs = np.exp(logits - logits.max(1, keepdims=True))
        probs /= probs.sum(1, keepdims=True)
        scores.append(probs.sum(0))
    scores = np.stack(scores)
    keep = _global_scores_keep(layers, scores, r * len(layers))
    return _apply_prune(cfg, params, list(keep), layers), {"keep": keep}


# ---------------------------------------------------------------------------
# O-prune — sampled subset search on layer-output deviation
# ---------------------------------------------------------------------------


def _layer_output(wg, wu, wd, router, x, keep_mask, cfg):
    """Reference MoE layer output on sample tokens with a keep mask."""
    from repro.models.layers import activation

    f = activation(cfg.act)
    logits = x @ router + np.where(keep_mask, 0.0, NEG)[None, :]
    m = cfg.moe
    if m.router_mode == "softmax_topk":
        idx = np.argsort(-logits, axis=1)[:, : m.top_k]
        sel = np.take_along_axis(logits, idx, axis=1)
        w = np.exp(sel - sel.max(1, keepdims=True))
        w /= w.sum(1, keepdims=True)
    else:
        full = np.exp(logits - logits.max(1, keepdims=True))
        full /= full.sum(1, keepdims=True)
        idx = np.argsort(-full, axis=1)[:, : m.top_k]
        w = np.take_along_axis(full, idx, axis=1) * m.routed_scaling_factor
    out = np.zeros((x.shape[0], x.shape[1]))
    for k in range(m.top_k):
        e_idx = idx[:, k]
        for e in np.unique(e_idx):
            rows = e_idx == e
            xe = x[rows]
            h = f(xe @ wg[e]) * (xe @ wu[e])
            out[rows] += w[rows, k][:, None] * (h @ wd[e])
    return out


def o_prune(cfg, params, stats, r: int, *, samples: int = 64, seed: int = 0):
    """Per-layer sampled subset search (the paper samples 10^5 on Qwen; we
    scale the sample count to the experiment)."""
    layers = flatten_stats(cfg, stats)
    rng = np.random.RandomState(seed)
    E = cfg.moe.num_experts
    keeps = []
    for l in layers:
        wg, wu, wd = _layer_weights(params, l["pattern_pos"], l["block"])
        moe_p = params["decoder"]["blocks"][f"layer{l['pattern_pos']}"]["moe"]
        router = np.asarray(moe_p["router"][l["block"]], np.float64)
        x = np.asarray(l["stats"].x_sample, np.float64)
        full_mask = np.ones(E, bool)
        ref = _layer_output(wg, wu, wd, router, x, full_mask, cfg)
        best, best_err = None, np.inf
        for _ in range(samples):
            cand = np.zeros(E, bool)
            cand[rng.choice(E, r, replace=False)] = True
            err = float(np.linalg.norm(
                ref - _layer_output(wg, wu, wd, router, x, cand, cfg)))
            if err < best_err:
                best, best_err = cand, err
        keeps.append(best)
    return _apply_prune(cfg, params, keeps, layers), {"keep": np.stack(keeps)}


# ---------------------------------------------------------------------------
# One-shot grouping (Table 6) and M-SMoE
# ---------------------------------------------------------------------------


def one_shot_grouping(feats: np.ndarray, freq: np.ndarray, r: int) -> np.ndarray:
    """Li et al. (2024): dominant = top-r by frequency; every other expert
    joins its most-similar dominant (single pass, no re-evaluation)."""
    E = feats.shape[0]
    dom = np.argsort(-freq, kind="stable")[:r]
    labels = np.full(E, -1, np.int64)
    for c, d_idx in enumerate(dom):
        labels[d_idx] = c
    for e in range(E):
        if labels[e] >= 0:
            continue
        d2 = ((feats[dom] - feats[e][None]) ** 2).sum(1)
        labels[e] = int(np.argmin(d2))
    return labels


def m_smoe(cfg, params, stats, r: int, *, metric: str = "router_logits",
           merge: str = "frequency"):
    """M-SMoE in the task-agnostic, no-retraining setting (paper §4.1)."""
    from repro.core.pipeline import build_combine_matrix, merge_stacked_jax

    layers = flatten_stats(cfg, stats)
    new_params = jax.tree.map(lambda x: x, params)
    positions = _moe_positions(cfg)
    by_pos = {p: [] for p in positions}
    info = []
    for l in layers:
        weights = _layer_weights(params, l["pattern_pos"], l["block"])
        feats = met.build_features(metric, stats=l["stats"], weights=weights)
        freq = np.asarray(l["stats"].freq, np.float64)
        labels = one_shot_grouping(feats, freq, r)
        by_pos[l["pattern_pos"]].append((l["block"], labels, freq))
        info.append({"labels": labels, "block": l["block"],
                     "pattern_pos": l["pattern_pos"]})
    for pos in positions:
        entries = sorted(by_pos[pos])
        moe = new_params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        combine = np.stack([
            build_combine_matrix(labels, freq, merge, r)
            for _, labels, freq in entries])
        mg, mu, md = merge_stacked_jax(moe["wg"], moe["wu"], moe["wd"],
                                       jnp.asarray(combine))
        moe["wg"], moe["wu"], moe["wd"] = mg, mu, md
        moe["group_map"] = jnp.asarray(
            np.stack([labels for _, labels, _ in entries]), jnp.int32)
    return new_params, {"layers": info}
