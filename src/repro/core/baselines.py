"""Retraining-free baselines reproduced from the paper (§4.1, Table 1).

  F-prune — global frequency-ranked expert pruning (dynamic per layer)
  S-prune — global router-score-ranked pruning (He et al., 2024)
  O-prune — per-layer subset search minimising layer-output deviation
            (Lu et al., 2024), with sampled search like the paper's 10^5 run
  M-SMoE  — frequency-dominant selection + router-logit one-shot grouping +
            frequency merging (Li et al., 2024), task-agnostic setting
  one_shot_grouping — Table 6's single-pass grouping under any metric

Every baseline is a PLAN PRODUCER registered in
:data:`repro.core.registry.PLANNERS`: it emits a
:class:`~repro.core.plan.MergePlan` (prune plans carry per-layer ``keep``
masks that become ``router_mask``; merge baselines carry combine matrices)
and :func:`~repro.core.plan.apply_plan` is the single write path into
params. Pruning writes ``router_mask`` (-1e9) so routing renormalises over
kept experts; weights of pruned experts are zeroed (ragged path then assigns
them zero tokens and zero FLOPs). The legacy ``f_prune(...) ->
(params, info)`` style entry points remain as thin shims.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import metrics as met
from repro.core.api import layer_weights, moe_params
from repro.core.calibration import flatten_stats
from repro.core.merging import build_combine_matrix
from repro.core.plan import (
    NEG, LayerPlan, MergePlan, PlanSpec, apply_plan, feature_fingerprint)
from repro.core.registry import register_planner

__all__ = [
    "NEG", "f_prune", "s_prune", "o_prune", "m_smoe", "one_shot_grouping",
    "f_prune_plan", "s_prune_plan", "o_prune_plan", "m_smoe_plan",
]


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _global_scores_keep(layers, scores: np.ndarray, keep_total: int):
    """Global top-k across (L, E) scores -> per-layer keep masks (dynamic)."""
    L, E = scores.shape
    order = np.argsort(-scores.reshape(-1), kind="stable")
    keep = np.zeros(L * E, bool)
    keep[order[:keep_total]] = True
    keep = keep.reshape(L, E)
    # every layer keeps at least one expert
    for l in range(L):
        if not keep[l].any():
            keep[l, int(np.argmax(scores[l]))] = True
    return keep


def _prune_plan(method: str, cfg, layers, keeps, spec: PlanSpec) -> MergePlan:
    E = cfg.moe.num_experts
    plan_layers = [
        LayerPlan(pattern_pos=l["pattern_pos"], block=l["block"],
                  target=int(np.asarray(k).sum()),
                  keep=np.asarray(k, bool),
                  freq=np.asarray(l["stats"].freq, np.float64))
        for l, k in zip(layers, keeps)]
    return MergePlan(kind="prune", method=method,
                     spec=dataclasses.asdict(spec), num_experts=E,
                     num_layers=len(plan_layers), slots=E,
                     layers=plan_layers)


def _spec(spec_or_r, method: str, **kw) -> PlanSpec:
    if isinstance(spec_or_r, PlanSpec):
        return spec_or_r
    return PlanSpec(target_experts=int(spec_or_r), method=method, **kw)


def _legacy_info(plan: MergePlan) -> dict:
    return {"keep": np.stack([lp.keep for lp in plan.layers]), "plan": plan}


# ---------------------------------------------------------------------------
# F-prune / S-prune
# ---------------------------------------------------------------------------


@register_planner("f_prune")
def f_prune_plan(cfg, params, stats, spec) -> MergePlan:
    spec = _spec(spec, "f_prune")
    layers = flatten_stats(cfg, stats)
    scores = np.stack([np.asarray(l["stats"].freq, np.float64)
                       for l in layers])
    keep = _global_scores_keep(layers, scores,
                               spec.target_experts * len(layers))
    return _prune_plan("f_prune", cfg, layers, list(keep), spec)


def f_prune(cfg, params, stats, r: int):
    plan = f_prune_plan(cfg, params, stats, r)
    return apply_plan(params, plan), _legacy_info(plan)


@register_planner("s_prune")
def s_prune_plan(cfg, params, stats, spec) -> MergePlan:
    """Router-score pruning: accumulate softmax router probs per expert."""
    spec = _spec(spec, "s_prune")
    layers = flatten_stats(cfg, stats)
    scores = []
    for l in layers:
        logits = np.asarray(l["stats"].logits_sample, np.float64)  # (T, E)
        probs = np.exp(logits - logits.max(1, keepdims=True))
        probs /= probs.sum(1, keepdims=True)
        scores.append(probs.sum(0))
    scores = np.stack(scores)
    keep = _global_scores_keep(layers, scores,
                               spec.target_experts * len(layers))
    return _prune_plan("s_prune", cfg, layers, list(keep), spec)


def s_prune(cfg, params, stats, r: int):
    plan = s_prune_plan(cfg, params, stats, r)
    return apply_plan(params, plan), _legacy_info(plan)


# ---------------------------------------------------------------------------
# O-prune — sampled subset search on layer-output deviation
# ---------------------------------------------------------------------------


def _layer_output(wg, wu, wd, router, x, keep_mask, cfg):
    """Reference MoE layer output on sample tokens with a keep mask."""
    from repro.models.layers import activation

    f = activation(cfg.act)
    logits = x @ router + np.where(keep_mask, 0.0, NEG)[None, :]
    m = cfg.moe
    if m.router_mode == "softmax_topk":
        idx = np.argsort(-logits, axis=1)[:, : m.top_k]
        sel = np.take_along_axis(logits, idx, axis=1)
        w = np.exp(sel - sel.max(1, keepdims=True))
        w /= w.sum(1, keepdims=True)
    else:
        full = np.exp(logits - logits.max(1, keepdims=True))
        full /= full.sum(1, keepdims=True)
        idx = np.argsort(-full, axis=1)[:, : m.top_k]
        w = np.take_along_axis(full, idx, axis=1) * m.routed_scaling_factor
    out = np.zeros((x.shape[0], x.shape[1]))
    for k in range(m.top_k):
        e_idx = idx[:, k]
        for e in np.unique(e_idx):
            rows = e_idx == e
            xe = x[rows]
            h = f(xe @ wg[e]) * (xe @ wu[e])
            out[rows] += w[rows, k][:, None] * (h @ wd[e])
    return out


@register_planner("o_prune")
def o_prune_plan(cfg, params, stats, spec) -> MergePlan:
    """Per-layer sampled subset search (the paper samples 10^5 on Qwen; we
    scale ``spec.samples`` to the experiment)."""
    spec = _spec(spec, "o_prune")
    layers = flatten_stats(cfg, stats)
    rng = np.random.RandomState(spec.seed)
    E = cfg.moe.num_experts
    r = spec.target_experts
    keeps = []
    for l in layers:
        wg, wu, wd = layer_weights(params, l["pattern_pos"], l["block"])
        moe_p = moe_params(params, l["pattern_pos"])
        router = np.asarray(moe_p["router"][l["block"]], np.float64)
        x = np.asarray(l["stats"].x_sample, np.float64)
        full_mask = np.ones(E, bool)
        ref = _layer_output(wg, wu, wd, router, x, full_mask, cfg)
        best, best_err = None, np.inf
        for _ in range(spec.samples):
            cand = np.zeros(E, bool)
            cand[rng.choice(E, r, replace=False)] = True
            err = float(np.linalg.norm(
                ref - _layer_output(wg, wu, wd, router, x, cand, cfg)))
            if err < best_err:
                best, best_err = cand, err
        keeps.append(best)
    return _prune_plan("o_prune", cfg, layers, keeps, spec)


def o_prune(cfg, params, stats, r: int, *, samples: int = 64, seed: int = 0):
    plan = o_prune_plan(cfg, params, stats,
                        PlanSpec(target_experts=r, method="o_prune",
                                 samples=samples, seed=seed))
    return apply_plan(params, plan), _legacy_info(plan)


# ---------------------------------------------------------------------------
# One-shot grouping (Table 6) and M-SMoE
# ---------------------------------------------------------------------------


def one_shot_grouping(feats: np.ndarray, freq: np.ndarray, r: int) -> np.ndarray:
    """Li et al. (2024): dominant = top-r by frequency; every other expert
    joins its most-similar dominant (single pass, no re-evaluation)."""
    E = feats.shape[0]
    dom = np.argsort(-freq, kind="stable")[:r]
    labels = np.full(E, -1, np.int64)
    for c, d_idx in enumerate(dom):
        labels[d_idx] = c
    for e in range(E):
        if labels[e] >= 0:
            continue
        d2 = ((feats[dom] - feats[e][None]) ** 2).sum(1)
        labels[e] = int(np.argmin(d2))
    return labels


@register_planner("m_smoe")
def m_smoe_plan(cfg, params, stats, spec) -> MergePlan:
    """M-SMoE in the task-agnostic, no-retraining setting (paper §4.1):
    one-shot grouping under ``spec.metric`` + ``spec.merge`` combine.

    The paper's M-SMoE groups on router logits — pass
    ``PlanSpec(metric="router_logits")`` (the legacy :func:`m_smoe` shim
    and the compress CLI default to it for this method)."""
    spec = _spec(spec, "m_smoe", metric="router_logits")
    layers = flatten_stats(cfg, stats)
    E = cfg.moe.num_experts
    r = spec.target_experts
    plan_layers = []
    for l in layers:
        weights = layer_weights(params, l["pattern_pos"], l["block"])
        feats = met.build_features(spec.metric, stats=l["stats"],
                                  weights=weights)
        freq = np.asarray(l["stats"].freq, np.float64)
        labels = one_shot_grouping(feats, freq, r)
        plan_layers.append(LayerPlan(
            pattern_pos=l["pattern_pos"], block=l["block"], target=r,
            labels=labels.astype(np.int32), freq=freq,
            combine=build_combine_matrix(labels, freq, spec.merge, r),
            feature_hash=feature_fingerprint(feats),
            extras={"features": feats}))
    return MergePlan(kind="merge", method="m_smoe",
                     spec=dataclasses.asdict(spec), num_experts=E,
                     num_layers=len(plan_layers), slots=r,
                     layers=plan_layers, default_executor="jax")


def _m_smoe_check_spec(spec: PlanSpec) -> None:
    """m_smoe merges through combine matrices only; reject feature-matching
    merges at PlanSpec construction (fail-fast), not after calibration."""
    # capability validation (fail-fast error message), not dispatch
    if spec.merge not in ("average", "frequency"):  # noqa: RPR006
        raise ValueError(
            f"method 'm_smoe' merges via combine matrices; merge must be "
            f"'average' or 'frequency', got {spec.merge!r}")


m_smoe_plan.check_spec = _m_smoe_check_spec
# M-SMoE groups experts by router-logit similarity (paper §4.1); CLI and
# callers read this instead of hard-coding the metric name per method.
m_smoe_plan.default_metric = "router_logits"


def m_smoe(cfg, params, stats, r: int, *, metric: str = "router_logits",
           merge: str = "frequency"):
    plan = m_smoe_plan(cfg, params, stats,
                       PlanSpec(target_experts=r, method="m_smoe",
                                metric=metric, merge=merge))
    info = [{"labels": np.asarray(lp.labels, np.int64), "block": lp.block,
             "pattern_pos": lp.pattern_pos} for lp in plan.layers]
    return apply_plan(params, plan), {"layers": info, "plan": plan}
