"""Pluggable registries for the compression pipeline's extension points.

Three registries replace the stringly-typed ``if/else`` dispatch that used
to live in ``pipeline.py`` / ``clustering.py`` / ``merging.py`` /
``metrics.py``:

  * ``METRICS``     — similarity feature builders: ``fn(stats, weights) ->
    (E, D) np.ndarray`` (paper §3.2.1).
  * ``CLUSTERINGS`` — expert grouping algorithms: ``fn(feats, r, *,
    linkage, seed) -> (labels, membership | None)`` (paper §3.2.2 / B.5).
  * ``MERGES``      — weight-space merge planners: ``fn(inputs:
    MergeInputs) -> {"combine": ...} | {"hidden_map": ...}`` (§3.2.3 / B.2).

Registering a new entry makes it reachable everywhere at once — config
validation (:class:`repro.core.pipeline.HCSMoEConfig`,
:class:`repro.core.plan.PlanSpec`), plan computation
(:func:`repro.core.plan.compute_plan`), and the ``launch/compress.py`` CLI —
with no edits to the dispatch sites::

    from repro.core.registry import register_metric

    @register_metric("router_weight")
    def router_weight_features(stats, weights):
        ...
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple


class Registry:
    """Name -> callable registry with a fail-fast, name-listing lookup."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Callable] = {}

    def register(self, name: str) -> Callable[[Callable], Callable]:
        def deco(fn: Callable) -> Callable:
            if name in self._entries:
                raise ValueError(
                    f"duplicate {self.kind} registration: {name!r}")
            self._entries[name] = fn
            return fn

        return deco

    def get(self, name: str) -> Callable:
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{', '.join(self.names())}") from None

    def validate(self, name: str) -> str:
        """Raise ValueError (listing valid names) unless ``name`` is
        registered; returns the name so callers can chain."""
        self.get(name)
        return name

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        return name in self._entries


METRICS = Registry("metric")
CLUSTERINGS = Registry("clustering")
MERGES = Registry("merge")
PLANNERS = Registry("planner")  # compression methods: hc_smoe, prunes, m_smoe

register_metric = METRICS.register
register_clustering = CLUSTERINGS.register
register_merge = MERGES.register
register_planner = PLANNERS.register
