"""HC-SMoE end-to-end pipeline (paper Alg. 1) — deprecated shim surface.

The pipeline was redesigned around the serializable
:class:`repro.core.plan.MergePlan` artifact: :func:`~repro.core.plan.
compute_plan` (calibration stats -> clustering -> merge description) and
:func:`~repro.core.plan.apply_plan` (description -> patched params), see
``docs/compression_api.md``. This module keeps the original entry points
alive as thin wrappers with identical outputs:

  * :func:`apply_hcsmoe` == ``apply_plan(params, compute_plan(...))`` plus
    the legacy ``info`` dict.
  * :func:`compute_groupings` — the plan's per-layer view in the old
    list-of-dicts shape.
  * ``build_combine_matrix`` / ``merge_stacked_jax`` re-exported from
    :mod:`repro.core.merging`.

New code should import from ``repro.core.plan`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core import plan as plan_mod
from repro.core.api import layer_weights as _public_layer_weights
from repro.core.api import moe_positions as _public_moe_positions
from repro.core.merging import (  # noqa: F401  (back-compat re-exports)
    build_combine_matrix, merge_stacked_jax)
from repro.core.plan import validate_spec_fields


@dataclass(frozen=True)
class HCSMoEConfig:
    target_experts: int
    linkage: str = "average"          # single | complete | average
    metric: str = "expert_output"     # registry: repro.core.registry.METRICS
    merge: str = "frequency"          # registry: MERGES
    clustering: str = "hc"            # registry: CLUSTERINGS
    fix_dom_feature: str = "act"      # act | weight | act+weight
    non_uniform: bool = False         # Appendix B.1
    resize: bool = True               # shrink stacked arrays to r slots
    seed: int = 0

    def __post_init__(self):
        # fail fast at construction: unknown metric/clustering/merge/
        # linkage/feature names never reach the pipeline
        validate_spec_fields(metric=self.metric, clustering=self.clustering,
                             merge=self.merge, linkage=self.linkage,
                             fix_dom_feature=self.fix_dom_feature)


# Deprecated private aliases: use repro.core.api instead.
_moe_positions = _public_moe_positions
_layer_weights = _public_layer_weights


def _groupings_from_plan(plan: plan_mod.MergePlan, cfg=None,
                         stats=None) -> List[dict]:
    by_key = {}
    if stats is not None:
        from repro.core.calibration import flatten_stats

        by_key = {(l["pattern_pos"], l["block"]): l["stats"]
                  for l in flatten_stats(cfg, stats)}
    out = []
    for lp in plan.layers:
        out.append({"pattern_pos": lp.pattern_pos, "block": lp.block,
                    "stats": by_key.get((lp.pattern_pos, lp.block)),
                    "labels": lp.labels,
                    "features": lp.extras.get("features"),
                    "freq": lp.freq,
                    "membership": lp.extras.get("membership"),
                    "r": lp.target})
    return out


def compute_groupings(cfg, params, stats, hc: HCSMoEConfig) -> List[dict]:
    """Deprecated: cluster every MoE layer, returning per-layer dicts.
    Use :func:`repro.core.plan.compute_plan`, which also carries the merge
    description and serializes."""
    return _groupings_from_plan(
        plan_mod.compute_plan(cfg, params, stats, hc), cfg, stats)


def apply_hcsmoe(cfg, params, stats, hc: HCSMoEConfig, *, use_jax_merge=None):
    """Deprecated one-shot path: ``apply_plan(params, compute_plan(...))``.

    Returns (new_params, info). Router weights are untouched; group_map
    redirects routed ids to merged slots (paper Fig. 3). ``info`` carries
    the computed plan under ``info["plan"]`` — save it with
    :func:`repro.checkpoint.save_plan` to re-apply without recalibrating."""
    plan = plan_mod.compute_plan(cfg, params, stats, hc)
    executor = None
    if use_jax_merge is not None:
        executor = "jax" if use_jax_merge else "numpy"
    new_params = plan_mod.apply_plan(params, plan, executor=executor)
    info = {"layers": _groupings_from_plan(plan, cfg, stats), "config": hc,
            "plan": plan}
    return new_params, info


# ---------------------------------------------------------------------------
# Convenience: full pipeline from a model + calib batches
# ---------------------------------------------------------------------------


def run_hcsmoe(model, params, calib_batches, hc: HCSMoEConfig):
    from repro.core.calibration import collect_moe_stats

    stats = collect_moe_stats(model, params, calib_batches)
    return apply_hcsmoe(model.cfg, params, stats, hc)
