"""HC-SMoE end-to-end pipeline: calibration stats -> clustering -> merging ->
patched model params (paper Alg. 1).

Two merge executors:
  * ``merge_layer`` (numpy) — offline reference, supports all four methods.
  * ``merge_stacked_jax`` — convex-combination merges (frequency/average)
    expressed as a single sharded einsum over the stacked (L, E, d, f)
    weights, so under pjit each TP/FSDP shard merges its slice locally with
    zero resharding. This is the TPU-native answer to the paper's
    single-host merge step (DESIGN.md §3) and is exercised by the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering as clu
from repro.core import merging as mrg
from repro.core import metrics as met
from repro.core.calibration import flatten_stats


@dataclass(frozen=True)
class HCSMoEConfig:
    target_experts: int
    linkage: str = "average"          # single | complete | average
    metric: str = "expert_output"     # expert_output | router_logits | weight
    merge: str = "frequency"          # frequency | average | fix_dom | zipit
    clustering: str = "hc"            # hc | kmeans_fix | kmeans_rnd | fcm
    fix_dom_feature: str = "act"      # act | weight | act+weight
    non_uniform: bool = False         # Appendix B.1
    resize: bool = True               # shrink stacked arrays to r slots
    seed: int = 0


def _moe_positions(cfg) -> List[int]:
    return [i for i, s in enumerate(cfg.pattern) if s.ffn == "moe"]


def _layer_weights(params, pos: int, block: int):
    moe = params["decoder"]["blocks"][f"layer{pos}"]["moe"]
    return (np.asarray(moe["wg"][block], np.float32),
            np.asarray(moe["wu"][block], np.float32),
            np.asarray(moe["wd"][block], np.float32))


def _per_layer_targets(cfg, layers, r: int, non_uniform: bool) -> List[int]:
    """Uniform r per layer, or Appendix-B.1 frequency-guided allocation."""
    L = len(layers)
    if not non_uniform:
        return [r] * L
    E = cfg.moe.num_experts
    freqs = np.stack([np.asarray(l["stats"].freq) for l in layers])  # (L, E)
    flat = freqs.reshape(-1)
    order = np.argsort(-flat, kind="stable")
    keep = order[: r * L]
    counts = np.bincount(keep // E, minlength=L)
    return [int(max(1, min(E, c))) for c in counts]


def compute_groupings(cfg, params, stats, hc: HCSMoEConfig) -> List[dict]:
    """Cluster every MoE layer. Returns per-layer dicts with labels etc."""
    layers = flatten_stats(cfg, stats)
    targets = _per_layer_targets(cfg, layers, hc.target_experts, hc.non_uniform)
    out = []
    for layer, r_l in zip(layers, targets):
        st = layer["stats"]
        weights = _layer_weights(params, layer["pattern_pos"], layer["block"])
        feats = met.build_features(hc.metric, stats=st, weights=weights)
        membership = None
        if hc.clustering == "fcm":
            labels, membership = clu.fcm_cluster(feats, r_l, seed=hc.seed)
        else:
            labels = clu.cluster(feats, r_l, method=hc.clustering,
                                 linkage=hc.linkage, seed=hc.seed)
        out.append({**layer, "labels": labels, "features": feats,
                    "freq": np.asarray(st.freq, np.float64),
                    "membership": membership, "r": r_l})
    return out


def merge_stacked_jax(wg, wu, wd, combine):
    """Sharded merge: combine (L, r, E) convex weights; w* (L, E, d, f)."""
    c = combine.astype(jnp.float32)
    mg = jnp.einsum("lre,ledf->lrdf", c, wg.astype(jnp.float32))
    mu = jnp.einsum("lre,ledf->lrdf", c, wu.astype(jnp.float32))
    md = jnp.einsum("lre,lefd->lrfd", c, wd.astype(jnp.float32))
    return mg.astype(wg.dtype), mu.astype(wu.dtype), md.astype(wd.dtype)


def build_combine_matrix(labels: np.ndarray, freq: np.ndarray, method: str,
                         num_slots: int) -> np.ndarray:
    """(num_slots, E) convex combination matrix from labels + frequencies."""
    alphas = mrg.cluster_alphas(labels, freq, method)
    E = labels.shape[0]
    M = np.zeros((num_slots, E), np.float32)
    M[labels, np.arange(E)] = alphas
    return M


def apply_hcsmoe(cfg, params, stats, hc: HCSMoEConfig, *, use_jax_merge=None):
    """Returns (new_params, info). Router weights are untouched; group_map
    redirects routed ids to merged slots (paper Fig. 3)."""
    groupings = compute_groupings(cfg, params, stats, hc)
    E = cfg.moe.num_experts
    resize = hc.resize and not hc.non_uniform
    n_slots = hc.target_experts if resize else E
    if use_jax_merge is None:
        use_jax_merge = hc.merge in ("frequency", "average") and hc.clustering != "fcm"

    new_params = jax.tree.map(lambda x: x, params)  # shallow-ish copy
    positions = _moe_positions(cfg)
    by_pos = {p: [g for g in groupings if g["pattern_pos"] == p] for p in positions}

    info = {"layers": groupings, "config": hc}
    for pos in positions:
        layers = sorted(by_pos[pos], key=lambda g: g["block"])
        moe = params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        if use_jax_merge:
            combine = np.stack([
                build_combine_matrix(g["labels"], g["freq"], hc.merge, n_slots)
                for g in layers])  # (n_blocks, n_slots, E)
            mg, mu, md = merge_stacked_jax(moe["wg"], moe["wu"], moe["wd"],
                                           jnp.asarray(combine))
        else:
            mgs, mus, mds = [], [], []
            for g in layers:
                wg_b, wu_b, wd_b = _layer_weights(params, pos, g["block"])
                g_, u_, d_, _ = mrg.merge_layer(
                    wg_b, wu_b, wd_b, g["labels"], g["freq"], hc.merge,
                    act_sample=np.asarray(g["stats"].act_sample),
                    feature=hc.fix_dom_feature, membership=g["membership"])
                r_l = g_.shape[0]
                if r_l < n_slots:  # pad dead slots with zeros
                    pad = ((0, n_slots - r_l), (0, 0), (0, 0))
                    g_, u_, d_ = (np.pad(g_, pad), np.pad(u_, pad), np.pad(d_, pad))
                mgs.append(g_)
                mus.append(u_)
                mds.append(d_)
            dt = moe["wg"].dtype
            mg = jnp.asarray(np.stack(mgs), dt)
            mu = jnp.asarray(np.stack(mus), dt)
            md = jnp.asarray(np.stack(mds), dt)
        group_map = jnp.asarray(np.stack([g["labels"] for g in layers]),
                                jnp.int32)
        tgt = new_params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        tgt["wg"], tgt["wu"], tgt["wd"] = mg, mu, md
        tgt["group_map"] = group_map
    return new_params, info


# ---------------------------------------------------------------------------
# Convenience: full pipeline from a model + calib batches
# ---------------------------------------------------------------------------


def run_hcsmoe(model, params, calib_batches, hc: HCSMoEConfig):
    from repro.core.calibration import collect_moe_stats

    stats = collect_moe_stats(model, params, calib_batches)
    return apply_hcsmoe(model.cfg, params, stats, hc)
