"""MergePlan: the serializable compression-plan artifact.

HC-SMoE is retraining-free, so a compression run is fully described by pure
data: which experts group together, how their weights combine, and which
slots survive. This module splits the old monolithic ``apply_hcsmoe`` into
two pure stages with that data as the interface:

  * :func:`compute_plan` ``(cfg, params, stats, spec) -> MergePlan`` —
    calibration-dependent, runs clustering + merge planning offline.
  * :func:`apply_plan` ``(params, plan) -> new_params`` — calibration-free,
    deterministic, re-runnable anywhere (serving load time, EP-sharded
    meshes, benchmark sweeps, draft-model construction).

A plan round-trips through JSON + npz (:func:`repro.checkpoint.save_plan` /
``load_plan``) and applying a reloaded plan is bit-identical to applying the
in-memory one. Provenance (method/metric/seed, expert count, layer count,
feature hashes) rides along so a plan can be audited (``launch/compress.py
inspect``) and a mismatched application fails fast
(:class:`PlanMismatchError`).

Two executors sit behind :func:`apply_plan`:

  * ``"jax"`` — combine-matrix plans collapse to one sharded einsum per MoE
    stack (:func:`repro.core.merging.merge_stacked_jax`), the EP/TP-safe
    path serving uses.
  * ``"numpy"`` — the float64 reference; required for ``hidden_map`` layers
    (fix_dom / zipit feature routing) and FCM's float64 soft memberships.

Prune baselines produce plans too (``kind="prune"``, per-layer keep masks
-> ``router_mask``), so ``apply_plan`` is the single write path into params.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import checked
from repro.core import api
from repro.core import clustering as clu
from repro.core import merging as mrg
from repro.core import metrics as _metrics  # noqa: F401  (registers METRICS)
from repro.core.calibration import flatten_stats
from repro.core.registry import (
    CLUSTERINGS, MERGES, METRICS, PLANNERS, register_planner)

NEG = -1.0e9  # router-mask logit for pruned experts

PLAN_FORMAT_VERSION = 1

# LayerPlan array fields that serialize to the npz side of a saved plan
LAYER_ARRAY_FIELDS = ("labels", "freq", "combine", "hidden_map", "keep")


class PlanMismatchError(ValueError):
    """A plan was applied to params it was not computed for."""


def validate_spec_fields(*, metric: str, clustering: str, merge: str,
                         linkage: str, fix_dom_feature: str) -> None:
    """Fail-fast validation shared by PlanSpec and HCSMoEConfig: unknown
    names raise at construction, not deep inside the pipeline."""
    METRICS.validate(metric)
    CLUSTERINGS.validate(clustering)
    MERGES.validate(merge)
    if linkage not in clu.LINKAGES:
        raise ValueError(
            f"unknown linkage {linkage!r}; valid: {', '.join(clu.LINKAGES)}")
    if fix_dom_feature not in mrg.FIX_DOM_FEATURES:
        raise ValueError(
            f"unknown fix_dom_feature {fix_dom_feature!r}; valid: "
            f"{', '.join(mrg.FIX_DOM_FEATURES)}")


@dataclass(frozen=True)
class PlanSpec:
    """What to compute a plan FOR — method + hyperparameters + seed.

    ``method`` selects a registered planner (``hc_smoe``, ``f_prune``,
    ``s_prune``, ``o_prune``, ``m_smoe``); the remaining fields mirror
    :class:`repro.core.pipeline.HCSMoEConfig` and are consumed by the
    planners that need them."""
    target_experts: int
    method: str = "hc_smoe"
    linkage: str = "average"          # single | complete | average
    metric: str = "expert_output"     # registry: METRICS
    merge: str = "frequency"          # registry: MERGES
    clustering: str = "hc"            # registry: CLUSTERINGS
    fix_dom_feature: str = "act"      # act | weight | act+weight
    non_uniform: bool = False         # Appendix B.1
    resize: bool = True               # shrink stacked arrays to r slots
    seed: int = 0
    samples: int = 64                 # o_prune subset-search budget

    def __post_init__(self):
        validate_spec_fields(metric=self.metric, clustering=self.clustering,
                             merge=self.merge, linkage=self.linkage,
                             fix_dom_feature=self.fix_dom_feature)
        # baselines register their planners on import; pull them in so the
        # method check sees the full registry
        import repro.core.baselines  # noqa: F401
        planner = PLANNERS.get(self.method)
        # planners may attach method-specific spec constraints (e.g. m_smoe
        # only merges via combine matrices) so bad combinations fail here,
        # at construction, not after a full calibration pass
        check = getattr(planner, "check_spec", None)
        if check is not None:
            check(self)

    @staticmethod
    def from_any(spec) -> "PlanSpec":
        """Accept a PlanSpec or an HCSMoEConfig-shaped object."""
        if isinstance(spec, PlanSpec):
            return spec
        fields = {f.name for f in dataclasses.fields(PlanSpec)}
        kw = {k: v for k, v in dataclasses.asdict(spec).items()
              if k in fields}
        return PlanSpec(**kw)


@dataclass
class LayerPlan:
    """One MoE layer's slice of a plan. Exactly one of the merge
    descriptions is set for ``kind="merge"`` plans (``combine`` or
    ``hidden_map``); ``keep`` is set for ``kind="prune"`` plans."""
    pattern_pos: int
    block: int
    target: int                              # live slots after compression
    labels: Optional[np.ndarray] = None      # (E,) int32 group map
    freq: Optional[np.ndarray] = None        # (E,) float64 activation freq
    combine: Optional[np.ndarray] = None     # (slots, E) convex weights
    hidden_map: Optional[np.ndarray] = None  # (E, f) int32 feature routing
    keep: Optional[np.ndarray] = None        # (E,) bool prune keep mask
    feature_hash: Optional[str] = None       # provenance of the features
    # in-memory only (never serialized): features / membership / stats for
    # quality reports and the deprecated compute_groupings surface
    extras: Dict = field(default_factory=dict, repr=False, compare=False)


@dataclass
class MergePlan:
    kind: str                 # "merge" | "prune"
    method: str               # planner name (provenance)
    spec: Dict                # full PlanSpec asdict (provenance)
    num_experts: int          # E the plan was computed for
    num_layers: int           # total MoE layers covered
    slots: int                # stacked expert-slot count after apply
    layers: List[LayerPlan] = field(default_factory=list)
    default_executor: str = "numpy"   # "jax" when every layer is combine

    def by_position(self) -> Dict[int, List[LayerPlan]]:
        """pattern_pos -> block-sorted layer plans."""
        out: Dict[int, List[LayerPlan]] = {}
        for lp in self.layers:
            out.setdefault(lp.pattern_pos, []).append(lp)
        return {p: sorted(ls, key=lambda lp: lp.block)
                for p, ls in sorted(out.items())}


def feature_fingerprint(feats: np.ndarray) -> str:
    """Stable short hash of a feature matrix (provenance / audit)."""
    f = np.ascontiguousarray(np.asarray(feats, np.float64))
    h = hashlib.sha256()
    h.update(str(f.shape).encode())
    h.update(f.tobytes())
    return h.hexdigest()[:16]


def per_layer_targets(cfg, layers, r: int, non_uniform: bool) -> List[int]:
    """Uniform r per layer, or Appendix-B.1 frequency-guided allocation."""
    L = len(layers)
    if not non_uniform:
        return [r] * L
    E = cfg.moe.num_experts
    freqs = np.stack([np.asarray(l["stats"].freq) for l in layers])  # (L, E)
    flat = freqs.reshape(-1)
    order = np.argsort(-flat, kind="stable")
    keep = order[: r * L]
    counts = np.bincount(keep // E, minlength=L)
    return [int(max(1, min(E, c))) for c in counts]


# ---------------------------------------------------------------------------
# Stage 1: compute_plan
# ---------------------------------------------------------------------------


def compute_plan(cfg, params, stats, spec) -> MergePlan:
    """Cluster + plan every MoE layer. Pure function of its inputs; the
    returned plan is self-contained — applying it never touches stats."""
    spec = PlanSpec.from_any(spec)
    import repro.core.baselines  # noqa: F401  (registers prune planners)
    return PLANNERS.get(spec.method)(cfg, params, stats, spec)


@register_planner("hc_smoe")
def _plan_hc_smoe(cfg, params, stats, spec: PlanSpec) -> MergePlan:
    """The paper's pipeline (Alg. 1): per-layer features -> clustering ->
    merge description."""
    layers = flatten_stats(cfg, stats)
    targets = per_layer_targets(cfg, layers, spec.target_experts,
                                spec.non_uniform)
    E = cfg.moe.num_experts
    resize = spec.resize and not spec.non_uniform
    n_slots = spec.target_experts if resize else E
    use_jax = (getattr(MERGES.get(spec.merge), "jax_executor", False)
               and getattr(CLUSTERINGS.get(spec.clustering),
                           "jax_executor", True))

    plan_layers = []
    for layer, r_l in zip(layers, targets):
        st = layer["stats"]
        weights = api.layer_weights(params, layer["pattern_pos"],
                                    layer["block"])
        feats = METRICS.get(spec.metric)(st, weights)
        labels, membership = CLUSTERINGS.get(spec.clustering)(
            feats, r_l, linkage=spec.linkage, seed=spec.seed)
        labels = np.asarray(labels)
        freq = np.asarray(st.freq, np.float64)
        if membership is not None:
            # soft clustering: U^T IS the combine matrix (Eq. 15), padded
            # with zero rows up to the stacked slot count
            combine = np.zeros((n_slots, E), np.float64)
            combine[: membership.shape[1]] = np.asarray(
                membership, np.float64).T
            payload = {"combine": combine}
        else:
            wg64, wu64, wd64 = (np.asarray(w, np.float64) for w in weights)
            merge_fn = MERGES.get(spec.merge)
            # only feature-matching merges read the calibration activation
            # sample; skip the (E, T, f) device->host copy otherwise
            act = (np.asarray(st.act_sample)
                   if getattr(merge_fn, "needs_act_sample", False) else None)
            payload = merge_fn(mrg.MergeInputs(
                labels=labels, freq=freq, wg=wg64, wu=wu64, wd=wd64,
                num_slots=n_slots, act_sample=act,
                feature=spec.fix_dom_feature))
        plan_layers.append(LayerPlan(
            pattern_pos=layer["pattern_pos"], block=layer["block"],
            target=r_l, labels=labels.astype(np.int32), freq=freq,
            combine=payload.get("combine"),
            hidden_map=payload.get("hidden_map"),
            feature_hash=feature_fingerprint(feats),
            # NOTE: extras deliberately excludes the stats object — a kept
            # plan must not pin the calibration capture (act samples) in
            # memory; the deprecated compute_groupings shim re-derives it
            extras={"features": feats, "membership": membership}))
    return MergePlan(kind="merge", method=spec.method,
                     spec=dataclasses.asdict(spec), num_experts=E,
                     num_layers=len(plan_layers), slots=n_slots,
                     layers=plan_layers,
                     default_executor="jax" if use_jax else "numpy")


# ---------------------------------------------------------------------------
# Stage 2: apply_plan
# ---------------------------------------------------------------------------


def _params_moe_by_pos(params) -> Dict[int, dict]:
    blocks = params["decoder"]["blocks"]
    return {int(name[len("layer"):]): grp["moe"]
            for name, grp in blocks.items() if "moe" in grp}


def check_plan_matches(params, plan: MergePlan) -> None:
    """Fail fast when plan provenance and params disagree (wrong expert
    count, wrong layer structure, wrong ffn width)."""
    if plan.num_layers != len(plan.layers):
        raise PlanMismatchError(
            f"corrupt plan: num_layers={plan.num_layers} but "
            f"{len(plan.layers)} layer entries")
    moe_by_pos = _params_moe_by_pos(params)
    by_pos = plan.by_position()
    if set(by_pos) != set(moe_by_pos):
        raise PlanMismatchError(
            f"plan covers MoE pattern positions {sorted(by_pos)} but params "
            f"have {sorted(moe_by_pos)}")
    for pos, lps in by_pos.items():
        wg = moe_by_pos[pos]["wg"]
        n_blocks, E, _, f = wg.shape
        if E != plan.num_experts:
            raise PlanMismatchError(
                f"plan was computed for {plan.num_experts} experts but "
                f"params at layer{pos} have {E}")
        if n_blocks != len(lps) or [lp.block for lp in lps] != list(
                range(n_blocks)):
            raise PlanMismatchError(
                f"plan covers blocks {[lp.block for lp in lps]} at "
                f"layer{pos} but params stack {n_blocks} blocks")
        for lp in lps:
            where = f"layer{pos}/block{lp.block}"
            if lp.hidden_map is not None and lp.hidden_map.shape != (E, f):
                raise PlanMismatchError(
                    f"{where}: hidden_map shape {lp.hidden_map.shape} vs "
                    f"expert ffn ({E}, {f})")
            if lp.combine is not None and lp.combine.shape != (plan.slots, E):
                raise PlanMismatchError(
                    f"{where}: combine shape {lp.combine.shape} vs "
                    f"(slots, E) = ({plan.slots}, {E})")
            if lp.labels is not None and lp.labels.shape != (E,):
                raise PlanMismatchError(
                    f"{where}: labels shape {lp.labels.shape} vs ({E},)")
            if lp.keep is not None and lp.keep.shape != (E,):
                raise PlanMismatchError(
                    f"{where}: keep mask shape {lp.keep.shape} vs ({E},)")


def _resolve_executor(plan: MergePlan, executor: Optional[str]) -> str:
    executor = executor or plan.default_executor
    if executor not in ("jax", "numpy"):
        raise ValueError(
            f"executor must be 'jax' or 'numpy', got {executor!r}")
    if executor == "jax" and any(lp.combine is None for lp in plan.layers):
        raise ValueError(
            "executor='jax' needs a combine matrix on every layer; "
            f"merge {plan.spec.get('merge')!r} plans hidden_map layers — "
            "use executor='numpy'")
    return executor


@checked(params=lambda p, _: isinstance(p, dict) and "decoder" in p,
         plan=lambda p, _: hasattr(p, "kind") and hasattr(p, "layers"),
         executor=lambda e, _: e in (None, "jax", "numpy"))
def apply_plan(params, plan: MergePlan, *, executor: Optional[str] = None):
    """Write a plan into a params pytree; returns new params (inputs are
    never mutated). Router weights are untouched: merge plans redirect
    routed ids through ``group_map`` (paper Fig. 3), prune plans mask
    router logits via ``router_mask`` so routing renormalises over kept
    experts."""
    check_plan_matches(params, plan)
    if plan.kind == "prune":
        return _apply_prune(params, plan)
    if plan.kind != "merge":
        raise ValueError(f"unknown plan kind {plan.kind!r}")
    executor = _resolve_executor(plan, executor)

    new_params = jax.tree.map(lambda x: x, params)  # shallow-ish copy
    for pos, lps in plan.by_position().items():
        moe = params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        if executor == "jax":
            combine = np.stack([lp.combine for lp in lps])
            mg, mu, md = mrg.merge_stacked_jax(
                moe["wg"], moe["wu"], moe["wd"], jnp.asarray(combine))
        else:
            mgs, mus, mds = [], [], []
            for lp in lps:
                wg, wu, wd = (np.asarray(w, np.float64)
                              for w in api.layer_weights(params, pos,
                                                         lp.block))
                if lp.combine is not None:
                    g_, u_, d_ = mrg.apply_combine_np(wg, wu, wd, lp.combine)
                else:
                    g_, u_, d_ = mrg.apply_hidden_map_np(
                        wg, wu, wd, lp.labels, lp.hidden_map, plan.slots)
                mgs.append(g_)
                mus.append(u_)
                mds.append(d_)
            dt = moe["wg"].dtype
            mg = jnp.asarray(np.stack(mgs), dt)
            mu = jnp.asarray(np.stack(mus), dt)
            md = jnp.asarray(np.stack(mds), dt)
        tgt = new_params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        tgt["wg"], tgt["wu"], tgt["wd"] = mg, mu, md
        tgt["group_map"] = jnp.asarray(
            np.stack([lp.labels for lp in lps]), jnp.int32)
    return new_params


def _apply_prune(params, plan: MergePlan):
    new_params = jax.tree.map(lambda x: x, params)
    for pos, lps in plan.by_position().items():
        mask = np.stack([lp.keep for lp in lps])  # (n_blocks, E)
        moe = new_params["decoder"]["blocks"][f"layer{pos}"]["moe"]
        rmask = jnp.where(jnp.asarray(mask), 0.0, NEG).astype(jnp.float32)
        moe["router_mask"] = rmask
        m = jnp.asarray(mask)[:, :, None, None]
        moe["wg"] = jnp.where(m, moe["wg"], 0)
        moe["wu"] = jnp.where(m, moe["wu"], 0)
        moe["wd"] = jnp.where(m, moe["wd"], 0)
    return new_params


# ---------------------------------------------------------------------------
# Inspection
# ---------------------------------------------------------------------------


def plan_summary(plan: MergePlan) -> str:
    """Human-readable provenance + shape report (``compress.py inspect``)."""
    spec = plan.spec
    lines = [
        f"MergePlan kind={plan.kind} method={plan.method} "
        f"(format v{PLAN_FORMAT_VERSION})",
        f"  experts: {plan.num_experts} -> {plan.slots} stacked slots, "
        f"{plan.num_layers} MoE layers",
        f"  spec: metric={spec.get('metric')} clustering="
        f"{spec.get('clustering')} linkage={spec.get('linkage')} "
        f"merge={spec.get('merge')} seed={spec.get('seed')} "
        f"non_uniform={spec.get('non_uniform')}",
        f"  default executor: {plan.default_executor}",
    ]
    for lp in plan.layers:
        desc = []
        if lp.keep is not None:
            desc.append(f"keep={int(lp.keep.sum())}/{lp.keep.shape[0]}")
        if lp.labels is not None:
            sizes = np.bincount(lp.labels, minlength=lp.target)
            desc.append("cluster_sizes=" +
                        ",".join(str(int(s)) for s in sizes[: lp.target]))
        if lp.combine is not None:
            desc.append(f"combine{lp.combine.shape}")
        if lp.hidden_map is not None:
            desc.append(f"hidden_map{lp.hidden_map.shape}")
        if lp.feature_hash:
            desc.append(f"feat#{lp.feature_hash}")
        lines.append(f"  layer pos={lp.pattern_pos} block={lp.block} "
                     f"target={lp.target}: " + " ".join(desc))
    return "\n".join(lines)
