"""Top-level model API: ``build_model(cfg)`` returns a :class:`Model` with
pure functions ``init / forward / loss_fn / train_loss / prefill / decode_step``
covering every assigned family (dense, moe, hybrid, ssm, encdec, vlm).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import embed, init_embedding, init_rms_norm, rms_norm, softcap, unembed
from repro.models.transformer import apply_stack, init_stack


def lm_cross_entropy(logits, labels, ignore_id: int = -100):
    """Mean token-level CE. logits (B,S,V) any float dtype; labels (B,S).

    Uses the one-hot-einsum formulation rather than take_along_axis: with the
    vocab axis TP-sharded, GSPMD turns the einsum into local partial sums +
    a tiny (B,S) all-reduce, where a dynamic gather would all-gather the full
    logits (33 GiB/device at train_4k scale — measured in the dry-run).
    """
    logits = logits.astype(jnp.float32)
    valid = labels != ignore_id
    labels_safe = jnp.where(valid, labels, 0)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    one_hot = jax.nn.one_hot(labels_safe, logits.shape[-1],
                             dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", one_hot, logits)
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


@dataclass
class Model:
    cfg: Any
    init: Callable
    forward: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    extend: Callable  # paged multi-token cached step (chunked prefill/decode)


def build_model(cfg) -> Model:
    is_encdec = cfg.family == "encdec"
    is_vlm = cfg.family == "vlm"

    # -------------------------------------------------------------- init
    def init(key) -> Dict:
        k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": init_embedding(k_emb, cfg.padded_vocab_size, cfg.d_model,
                                    cfg.dtype),
            "final_norm": init_rms_norm(cfg.d_model),
        }
        params["decoder"] = init_stack(k_dec, cfg, with_cross=is_encdec)
        if is_encdec:
            import dataclasses as _dc

            enc_cfg = _dc.replace(cfg, pattern=cfg.encoder_pattern or cfg.pattern,
                                  num_layers=cfg.encoder_layers,
                                  first_dense_layers=0, encoder_layers=0,
                                  encoder_pattern=())
            params["encoder"] = init_stack(k_enc, enc_cfg, with_cross=False)
            params["enc_norm"] = init_rms_norm(cfg.d_model)
        if not cfg.tie_embeddings:
            from repro.models.layers import dense_init

            params["lm_head"] = dense_init(
                k_head, (cfg.d_model, cfg.padded_vocab_size),
                jnp.dtype(cfg.dtype))
        return params

    def _enc_cfg():
        import dataclasses as _dc

        return _dc.replace(cfg, pattern=cfg.encoder_pattern or cfg.pattern,
                           num_layers=cfg.encoder_layers, first_dense_layers=0,
                           encoder_layers=0, encoder_pattern=())

    def _logits(params, h, pc=None):
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = unembed(h, params["embed"], transpose=True)
        else:
            head = params["lm_head"]
            if pc is not None and pc.weight_gather and pc.fsdp_axis:
                from jax.sharding import PartitionSpec as _P

                from repro.parallel.sharding import _mesh_in_context

                if _mesh_in_context():
                    head = jax.lax.with_sharding_constraint(
                        head, _P(None, pc.tp_axis))
            logits = unembed(h, head, transpose=False)
        logits = softcap(logits, cfg.final_logit_softcap)
        if cfg.padded_vocab_size != cfg.vocab_size:
            # mask the padding ids (AFTER softcap so they stay -inf-like)
            pad_mask = (jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size)
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                               logits)
        return logits

    def _encode(params, src_frames, *, moe_mode, remat="none",
                unroll: bool = False, pc=None):
        B, S, _ = src_frames.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        h, _, _ = apply_stack(params["encoder"], _enc_cfg(), src_frames, positions,
                              mode="train", mask_kind="full", moe_mode=moe_mode,
                              remat=remat, unroll=unroll, pc=pc)
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _decoder_inputs(params, tokens=None, patch_embeds=None):
        """Returns (hidden, positions). VLM prepends patch embeddings."""
        tok_emb = embed(tokens, params["embed"]) if tokens is not None else None
        if is_vlm and patch_embeds is not None:
            h = jnp.concatenate([patch_embeds.astype(tok_emb.dtype), tok_emb], axis=1)
        else:
            h = tok_emb
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return h, positions

    # ------------------------------------------------------------ forward
    def forward(params, *, tokens=None, patch_embeds=None, src_frames=None,
                moe_mode: str = "ragged", capture_stats: bool = False,
                remat: str = "none", mode: str = "train",
                unroll: bool = False, pc=None):
        """Full-sequence forward. Returns (logits, aux)."""
        enc_out = None
        if is_encdec:
            enc_out = _encode(params, src_frames, moe_mode=moe_mode,
                              remat=remat, unroll=unroll, pc=pc)
        h, _, aux = apply_stack(
            params["decoder"], cfg, *_decoder_inputs(params, tokens, patch_embeds),
            mode="train", moe_mode=moe_mode, capture_stats=capture_stats,
            enc_out=enc_out, remat=remat, unroll=unroll, pc=pc)
        return _logits(params, h, pc), aux

    def _chunked_ce(params, h, labels, pc, chunk: int = 1024):
        """Big-vocab CE without ever materialising (B, S, V) logits: scan
        over sequence chunks with a rematted body; each chunk projects to
        logits, reduces to per-chunk (nll_sum, count), and is freed."""
        from repro.models.flags import chunking as _chunking

        B, S, d = h.shape
        chunk, _unroll_ce = _chunking(S, chunk) if S >= 4 else (chunk, False)
        pad = (-S) % chunk
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        n = h.shape[1] // chunk
        hs = h.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

        def body(carry, xs):
            hc, lc = xs
            logits = _logits(params, hc, pc).astype(jnp.float32)
            valid = lc != -100
            safe = jnp.where(valid, lc, 0)
            m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
            logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
            one_hot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
            gold = jnp.einsum("bsv,bsv->bs", one_hot, logits)
            nll = jnp.sum((logz - gold) * valid)
            return (carry[0] + nll, carry[1] + jnp.sum(valid)), None

        (tot, cnt), _ = jax.lax.scan(
            jax.checkpoint(body, prevent_cse=_unroll_ce),
            (jnp.zeros(()), jnp.zeros((), jnp.int32)),
            (hs, ls), unroll=n if _unroll_ce else 1)
        return tot / jnp.maximum(cnt, 1)

    # --------------------------------------------------------- train loss
    def train_loss(params, batch, *, moe_mode: str = "ragged",
                   remat: str = "full", lb_coef: float = 0.01,
                   z_coef: float = 1e-3, unroll: bool = False, pc=None):
        tokens = batch["tokens"]
        labels = batch["labels"]
        enc_out = None
        if is_encdec:
            enc_out = _encode(params, batch["src_frames"], moe_mode=moe_mode,
                              remat=remat, unroll=unroll, pc=pc)
        h, positions = _decoder_inputs(params, tokens,
                                       batch.get("patch_embeds"))
        h, _, aux = apply_stack(params["decoder"], cfg, h, positions,
                                mode="train", moe_mode=moe_mode,
                                enc_out=enc_out, remat=remat, unroll=unroll,
                                pc=pc)
        if is_vlm and "patch_embeds" in batch:
            n_img = batch["patch_embeds"].shape[1]
            h = h[:, n_img:]
        ce = _chunked_ce(params, h[:, :-1], labels[:, 1:], pc,
                         chunk=min(1024, max(1, h.shape[1] - 1)))
        loss = ce + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
        metrics = {"ce": ce, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"],
                   "loss": loss}
        return loss, metrics

    # ------------------------------------------------------------ prefill
    def prefill(params, *, tokens=None, patch_embeds=None, src_frames=None,
                cache_max_len: int = 0, moe_mode: str = "ragged",
                last_pos=None, unroll: bool = False, pc=None):
        """Returns (last-token logits, cache).

        ``last_pos``: optional (B,) int32 per-row index of the last REAL
        token. Serving buckets right-pad prompts to a shared length; with
        causal masking the hidden state at each row's true last position is
        unaffected by padding, so gathering there yields exact logits while
        the compiled shape stays one-per-bucket.
        """
        enc_out = None
        if is_encdec:
            enc_out = _encode(params, src_frames, moe_mode=moe_mode,
                              unroll=unroll, pc=pc)
        h, positions = _decoder_inputs(params, tokens, patch_embeds)
        cache_max_len = cache_max_len or (h.shape[1] if not is_encdec
                                          else max(h.shape[1], enc_out.shape[1]))
        h, cache, _ = apply_stack(params["decoder"], cfg, h, positions,
                                  mode="prefill", cache_max_len=cache_max_len,
                                  moe_mode=moe_mode, enc_out=enc_out,
                                  unroll=unroll, pc=pc)
        if last_pos is not None:
            h_last = jnp.take_along_axis(
                h, jnp.asarray(last_pos, jnp.int32)[:, None, None], axis=1)
        else:
            h_last = h[:, -1:]
        return _logits(params, h_last, pc), cache

    # -------------------------------------------------------- decode step
    def decode_step(params, *, tokens, cache, moe_mode: str = "ragged",
                    unroll: bool = False, pc=None):
        """tokens: (B, 1). Returns (logits (B,1,V), new cache)."""
        pos = cache["pos"]  # (B,)
        if "page_table" in cache:
            raise ValueError(
                "decode_step got a PAGED cache; use model.extend(tokens, "
                "cache, valid) — a single-token extend IS the paged decode "
                "step")
        h = embed(tokens, params["embed"])
        h, new_cache, _ = apply_stack(params["decoder"], cfg, h, pos,
                                      mode="decode", cache=cache,
                                      moe_mode=moe_mode, unroll=unroll, pc=pc)
        return _logits(params, h, pc), new_cache

    # ------------------------------------------------- extend (paged cache)
    def extend(params, *, tokens, cache, valid, moe_mode: str = "ragged",
               unroll: bool = False, pc=None, all_logits: bool = False):
        """Multi-token cached step over a PAGED cache (see
        :mod:`repro.models.kvcache`).

        tokens: (B, C); valid: (B,) int32 — row counts actually appended per
        slot (0 freezes a slot entirely: its writes are redirected to the
        null page and its ``pos`` does not advance). ``C == 1`` with
        ``valid = 1`` is a decode step; ``C > 1`` is one chunk of a chunked
        prefill — both run the same compiled function shape-per-C. Returns
        (logits (B, 1, V) gathered at each slot's LAST VALID row, new
        cache). Rows at or beyond ``valid`` contribute nothing to any live
        slot's cache or logits.

        ``all_logits=True`` returns logits at EVERY row — (B, C, V) — the
        speculative-decoding verifier shape: row ``j`` holds the target
        distribution for the token following ``tokens[:, j]``, so one
        extend call scores a whole draft run (rows >= ``valid`` are
        garbage and must be ignored by the caller).
        """
        from repro.models.kvcache import paged_write_coords

        pos = cache["pos"]
        page_table = cache["page_table"]
        kv_pos = cache["kv_pos"]
        C = tokens.shape[1]
        page = kv_pos.shape[1]
        valid = jnp.asarray(valid, jnp.int32)
        flat, positions, kv_vals = paged_write_coords(
            page_table, pos, C, page, valid)
        new_kv_pos = kv_pos.reshape(-1).at[flat.reshape(-1)].set(
            kv_vals.reshape(-1)).reshape(kv_pos.shape)
        paged = {"positions": positions, "pos": pos, "valid": valid,
                 "flat": flat, "kv_pos": new_kv_pos,
                 "page_table": page_table, "page_size": page}
        h = embed(tokens, params["embed"])
        h, new_cache, _ = apply_stack(params["decoder"], cfg, h, positions,
                                      mode="extend", cache=cache,
                                      moe_mode=moe_mode, unroll=unroll,
                                      pc=pc, paged=paged)
        if all_logits:
            return _logits(params, h, pc), new_cache
        idx = jnp.maximum(valid - 1, 0)[:, None, None]
        h_last = jnp.take_along_axis(h, idx, axis=1)
        return _logits(params, h_last, pc), new_cache

    return Model(cfg=cfg, init=init, forward=forward, train_loss=train_loss,
                 prefill=prefill, decode_step=decode_step, extend=extend)
