"""Decode-time cache construction: zeros + specs (via eval_shape, no alloc).

Two cache layouts coexist:

**Contiguous** (the PR-1..3 layout) mirrors the scanned block structure:
  {"pos": (B,) int32,
   "prefix": (per prefix layer dict,),
   "blocks": (per pattern-position dict, leaves stacked over n_blocks)}
Attention layers use a ring buffer of length ``cache_window`` (= sliding
window for local layers); recurrent mixers carry O(1) state. Every slot
owns ``max_len`` rows up front — KV memory is provisioned for the worst
case.

**Paged** (vLLM-style) replaces the per-slot ring buffers with a shared
pool of fixed-size pages:
  {"pos": (B,) int32,
   "page_table": (B, P) int32        # logical page -> physical page id
   "kv_pos":     (N, page) int32     # shared across layers (-1 = unfilled)
   "prefix": (per layer {"k","v"} pools,),
   "blocks": (stacked {"k","v"} pools,)}
where every attention layer's k/v pool is ``(N, page, K, hd)``. Page id 0
is a reserved **null page** that is never allocated: unassigned page-table
entries point at it, its ``kv_pos`` rows stay -1 forever, so gathers
through unallocated entries are masked rather than garbage (the same trick
the flash-decode kernel's DMA-eliding clamp relies on). There is no ring
wrap in paged mode — logical row == absolute position — which is
token-identical to the contiguous path because a ring only ever overwrites
positions the sliding-window mask has already excluded.

Physical pages are handed out by the host-side :class:`PageAllocator`
(free-list alloc on admission/growth, release on retirement); KV memory
scales with the tokens actually resident, not ``slots * max_len``.
Paging supports attention-family mixers only (:func:`supports_paging`).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.analysis.contracts import contracts_enabled
from repro.models.attention import cache_window

PAGEABLE_MIXERS = ("attn", "attn_local", "attn_global")


def _layer_cache(cfg, spec, batch, max_len, dtype):
    B = batch
    mixer = spec.mixer
    if mixer in ("attn", "attn_local", "attn_global"):
        W = cache_window(cfg, mixer, max_len)
        c = {
            "k": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "kv_pos": jnp.full((B, W), -1, jnp.int32),
        }
        if cfg.family == "encdec":
            c["ck"] = jnp.zeros((B, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["cv"] = jnp.zeros((B, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["c_len"] = jnp.zeros((B,), jnp.int32)  # valid encoder length
        return c
    if mixer == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((B, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((B, max_len, m.qk_rope_head_dim), dtype),
            "kv_pos": jnp.full((B, max_len), -1, jnp.int32),
        }
    if mixer == "mamba":
        mc = cfg.mamba
        d_in = mc.expand * cfg.d_model
        return {
            "ssm": jnp.zeros((B, d_in, mc.d_state), jnp.float32),
            "conv": jnp.zeros((B, mc.d_conv - 1, d_in), dtype),
        }
    if mixer == "mlstm":
        xc = cfg.xlstm
        d_in = int(xc.mlstm_proj_factor * cfg.d_model)
        dk = d_in // xc.num_heads
        return {
            "C": jnp.zeros((B, xc.num_heads, dk, dk), jnp.float32),
            "n": jnp.zeros((B, xc.num_heads, dk), jnp.float32),
            "m": jnp.zeros((B, xc.num_heads), jnp.float32),
            "conv": jnp.zeros((B, xc.conv1d_kernel - 1, d_in), dtype),
        }
    if mixer == "slstm":
        d = cfg.d_model
        return {
            "c": jnp.zeros((B, d), jnp.float32),
            "n": jnp.zeros((B, d), jnp.float32),
            "h": jnp.zeros((B, d), jnp.float32),
            "m": jnp.full((B, d), -1.0e30, jnp.float32),  # matches slstm_forward init
        }
    raise ValueError(mixer)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    prefix_spec = cfg.pattern[0]
    prefix = tuple(
        _layer_cache(cfg, type(prefix_spec)(mixer=prefix_spec.mixer, ffn="dense"),
                     batch, max_len, dtype)
        for _ in range(cfg.first_dense_layers)
    )

    def stack(fn, n):
        leaves = [fn() for _ in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    blocks = tuple(
        stack(partial(_layer_cache, cfg, spec, batch, max_len, dtype),
              cfg.num_blocks)
        for spec in cfg.pattern
    )
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "prefix": prefix,
        "blocks": blocks,
    }


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the cache — zero allocation."""
    return jax.eval_shape(
        partial(init_cache, cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# Paged KV cache
# ---------------------------------------------------------------------------


def supports_paging(cfg) -> bool:
    """True when the paged layout covers every layer's cache: attention-family
    mixers only (MLA / recurrent mixers keep their own state layouts) and no
    encoder/cross-attention side caches."""
    if cfg.family in ("encdec", "vlm"):
        return False
    mixers = {s.mixer for s in cfg.pattern}
    if cfg.first_dense_layers:
        mixers.add(cfg.pattern[0].mixer)
    return mixers <= set(PAGEABLE_MIXERS)


class PageExhausted(RuntimeError):
    """The page pool has no free page for a required allocation."""


class PageAllocator:
    """Host-side free-list allocator for the paged KV pool.

    Pages ``1..num_pages-1`` are allocatable; page 0 is the reserved null
    page. Each serving slot owns an ordered list of pages covering its
    logical rows ``[0, len)``; :meth:`ensure` grows a slot on demand
    (admission, chunked prefill, decode crossing a page boundary) and
    :meth:`release` returns every page of a retired slot to the free list.

    :meth:`reserve` is the admission-time backpressure primitive of the
    engine's "reserve" policy: it budgets a slot's WORST-CASE page count
    (prompt + max_new rows) against :attr:`pages_available` without
    allocating anything, so later :meth:`ensure` growth — a decode step
    crossing a page boundary, the next prefill chunk — can never exhaust
    the pool mid-request. Physical pages are still handed out lazily;
    reservations are pure accounting. The engine's default "optimistic"
    policy never reserves: it admits against the free list directly and
    answers a failed :meth:`ensure` by preempting a resident slot
    (:meth:`release` both frees the pages and drops any reservation, so
    preemption and retirement share one exit path).

    Invariants (property-tested): a physical page is owned by at most one
    slot, ``free + owned == num_pages - 1`` at all times, and
    ``pages_available >= 0``.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"num_pages ({num_pages}) must be >= 2 "
                             "(page 0 is the reserved null page)")
        if page_size < 1:
            raise ValueError(f"page_size ({page_size}) must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}   # slot -> budgeted page count

    # ------------------------------------------------------------ queries
    @property
    def pages_in_use(self) -> int:
        return sum(len(v) for v in self._owned.values())

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_available(self) -> int:
        """Free pages not spoken for by an outstanding reservation."""
        unbacked = sum(max(r - len(self._owned.get(s, ())), 0)
                       for s, r in self._reserved.items())
        return len(self._free) - unbacked

    def pages_for(self, n_rows: int) -> int:
        """Pages needed to hold ``n_rows`` logical rows."""
        return -(-max(n_rows, 0) // self.page_size)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def reserved(self, slot: int) -> int:
        """The slot's budgeted page count (0 if nothing reserved)."""
        return self._reserved.get(slot, 0)

    # ---------------------------------------------------------- mutation
    def reserve(self, slot: int, n_rows: int):
        """Budget pages so ``slot`` can grow to ``n_rows`` rows without
        ever failing an :meth:`ensure`. Raises :class:`PageExhausted` —
        with nothing recorded — if the unreserved pool cannot cover it."""
        need = self.pages_for(n_rows)
        backed = max(self._reserved.get(slot, 0),
                     len(self._owned.get(slot, ())))
        grow = need - backed
        if grow <= 0:
            return
        if grow > self.pages_available:
            raise PageExhausted(
                f"slot {slot} needs a budget of {need} page(s) for "
                f"{n_rows} rows but only {self.pages_available} of "
                f"{self.num_pages - 1} are unreserved (raise kv_pages or "
                "admit fewer requests)")
        self._reserved[slot] = need

    def ensure(self, slot: int, n_rows: int) -> List[int]:
        """Grow ``slot`` to cover rows ``[0, n_rows)``; returns the newly
        allocated page ids (empty if already covered). Raises
        :class:`PageExhausted` — with the slot untouched — if the pool
        cannot satisfy the growth."""
        have = self._owned.setdefault(slot, [])
        need = self.pages_for(n_rows) - len(have)
        if need <= 0:
            return []
        if need > len(self._free):
            raise PageExhausted(
                f"slot {slot} needs {need} more page(s) for {n_rows} rows "
                f"but only {len(self._free)} of {self.num_pages - 1} are "
                "free (raise kv_pages or shrink the admitted batch)")
        fresh = [self._free.pop() for _ in range(need)]
        have.extend(fresh)
        if contracts_enabled():
            self._check_invariants()
        return fresh

    def release(self, slot: int) -> List[int]:
        """Free every page of ``slot`` (and drop its reservation); returns
        the released page ids."""
        self._reserved.pop(slot, None)
        pages = self._owned.pop(slot, [])
        self._free.extend(pages)
        if contracts_enabled():
            self._check_invariants()
        return pages

    def _check_invariants(self) -> None:
        """The property-tested allocator invariants, asserted inline under
        REPRO_CONTRACTS (tests/CI); never called in production."""
        owned_pages = [p for pages in self._owned.values() for p in pages]
        assert len(owned_pages) == len(set(owned_pages)), (
            "page owned by more than one slot")
        assert 0 not in owned_pages and 0 not in self._free, (
            "null page 0 entered circulation")
        assert len(self._free) + len(owned_pages) == self.num_pages - 1, (
            f"page leak: {len(self._free)} free + {len(owned_pages)} owned "
            f"!= {self.num_pages - 1}")
        assert self.pages_available >= 0, "reservations exceed the pool"

    def table_row(self, slot: int, table_len: int):
        """The slot's page table row, null-padded to ``table_len``."""
        import numpy as np

        row = np.zeros((table_len,), np.int32)
        pages = self._owned.get(slot, ())
        row[:len(pages)] = pages
        return row


def _attn_layer_counts(cfg) -> int:
    """Number of attention-layer caches (prefix + per-block pattern slots)."""
    return cfg.first_dense_layers + cfg.num_blocks * len(cfg.pattern)


def init_paged_cache(cfg, batch: int, max_len: int, *, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    """Device-side paged cache pytree (see module docstring for layout)."""
    if not supports_paging(cfg):
        raise ValueError(
            f"{cfg.name}: paged KV requires attention-family mixers only")
    if max_len % page_size:
        raise ValueError(f"max_len ({max_len}) must be a multiple of "
                         f"kv_page_size ({page_size})")
    K, hd = cfg.num_kv_heads, cfg.head_dim
    P = max_len // page_size

    def pool():
        return {
            "k": jnp.zeros((num_pages, page_size, K, hd), dtype),
            "v": jnp.zeros((num_pages, page_size, K, hd), dtype),
        }

    prefix = tuple(pool() for _ in range(cfg.first_dense_layers))
    blocks = tuple(
        jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[pool() for _ in range(cfg.num_blocks)])
        for _ in cfg.pattern
    )
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "page_table": jnp.zeros((batch, P), jnp.int32),
        "kv_pos": jnp.full((num_pages, page_size), -1, jnp.int32),
        "prefix": prefix,
        "blocks": blocks,
    }


def paged_cache_specs(cfg, batch: int, max_len: int, *, num_pages: int,
                      page_size: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the paged cache — zero allocation. The
    paged analogue of :func:`cache_specs`, used to derive shardings for the
    engine's jitted extend/decode path under a mesh."""
    return jax.eval_shape(
        partial(init_paged_cache, cfg, batch, max_len, num_pages=num_pages,
                page_size=page_size, dtype=dtype))


def paged_write_coords(page_table, pos, n_tokens: int, page_size: int,
                       valid):
    """Flat pool-row indices for writing ``n_tokens`` rows per slot starting
    at ``pos``. Rows at or beyond ``valid[b]`` are redirected to flat index 0
    (null page, row 0) so dead slots / tail padding never corrupt live pages;
    their ``kv_pos`` value is -1. Returns (flat (B, C) int32 into the
    ``(N * page,)``-flattened pool, positions (B, C), kv_pos_vals (B, C))."""
    C = n_tokens
    offs = pos[:, None].astype(jnp.int32) + jnp.arange(C, dtype=jnp.int32)
    P = page_table.shape[1]
    pi = jnp.clip(offs // page_size, 0, P - 1)
    phys = jnp.take_along_axis(page_table, pi, axis=1)
    flat = phys * page_size + offs % page_size
    ok = jnp.arange(C, dtype=jnp.int32)[None] < valid[:, None]
    return (jnp.where(ok, flat, 0), offs,
            jnp.where(ok, offs, jnp.int32(-1)))


def gather_paged_kv(pool, page_table):
    """Materialise the logical view of a paged pool for the jnp backend:
    pool (N, page, ...) gathered by page_table (B, P) -> (B, P*page, ...).
    Unallocated entries gather the null page (kv_pos -1 -> masked)."""
    g = jnp.take(pool, page_table, axis=0)  # (B, P, page, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_kv_page_bytes(cfg, page_size: int) -> int:
    """HBM bytes one physical page costs across every attention layer
    (k + v pools) plus its shared kv_pos rows."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    kv = 2 * page_size * cfg.num_kv_heads * cfg.head_dim * itemsize
    return _attn_layer_counts(cfg) * kv + page_size * 4  # + int32 kv_pos


def contiguous_kv_bytes(cfg, batch: int, max_len: int) -> int:
    """What the contiguous layout provisions up front: every slot owns a
    ``cache_window``-row ring (+ kv_pos) in every attention layer."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    total = 0
    specs = [cfg.pattern[0].mixer] * cfg.first_dense_layers + \
        [s.mixer for s in cfg.pattern] * cfg.num_blocks
    for mixer in specs:
        W = cache_window(cfg, mixer, max_len)
        total += batch * W * (
            2 * cfg.num_kv_heads * cfg.head_dim * itemsize + 4)
    return total
