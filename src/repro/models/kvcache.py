"""Decode-time cache construction: zeros + specs (via eval_shape, no alloc).

Cache layout mirrors the scanned block structure:
  {"pos": (B,) int32,
   "prefix": (per prefix layer dict,),
   "blocks": (per pattern-position dict, leaves stacked over n_blocks)}
Attention layers use a ring buffer of length ``cache_window`` (= sliding
window for local layers); recurrent mixers carry O(1) state.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.attention import cache_window


def _layer_cache(cfg, spec, batch, max_len, dtype):
    B = batch
    mixer = spec.mixer
    if mixer in ("attn", "attn_local", "attn_global"):
        W = cache_window(cfg, mixer, max_len)
        c = {
            "k": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "kv_pos": jnp.full((B, W), -1, jnp.int32),
        }
        if cfg.family == "encdec":
            c["ck"] = jnp.zeros((B, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["cv"] = jnp.zeros((B, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["c_len"] = jnp.zeros((B,), jnp.int32)  # valid encoder length
        return c
    if mixer == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((B, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((B, max_len, m.qk_rope_head_dim), dtype),
            "kv_pos": jnp.full((B, max_len), -1, jnp.int32),
        }
    if mixer == "mamba":
        mc = cfg.mamba
        d_in = mc.expand * cfg.d_model
        return {
            "ssm": jnp.zeros((B, d_in, mc.d_state), jnp.float32),
            "conv": jnp.zeros((B, mc.d_conv - 1, d_in), dtype),
        }
    if mixer == "mlstm":
        xc = cfg.xlstm
        d_in = int(xc.mlstm_proj_factor * cfg.d_model)
        dk = d_in // xc.num_heads
        return {
            "C": jnp.zeros((B, xc.num_heads, dk, dk), jnp.float32),
            "n": jnp.zeros((B, xc.num_heads, dk), jnp.float32),
            "m": jnp.zeros((B, xc.num_heads), jnp.float32),
            "conv": jnp.zeros((B, xc.conv1d_kernel - 1, d_in), dtype),
        }
    if mixer == "slstm":
        d = cfg.d_model
        return {
            "c": jnp.zeros((B, d), jnp.float32),
            "n": jnp.zeros((B, d), jnp.float32),
            "h": jnp.zeros((B, d), jnp.float32),
            "m": jnp.full((B, d), -1.0e30, jnp.float32),  # matches slstm_forward init
        }
    raise ValueError(mixer)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    prefix_spec = cfg.pattern[0]
    prefix = tuple(
        _layer_cache(cfg, type(prefix_spec)(mixer=prefix_spec.mixer, ffn="dense"),
                     batch, max_len, dtype)
        for _ in range(cfg.first_dense_layers)
    )

    def stack(fn, n):
        leaves = [fn() for _ in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    blocks = tuple(
        stack(partial(_layer_cache, cfg, spec, batch, max_len, dtype),
              cfg.num_blocks)
        for spec in cfg.pattern
    )
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "prefix": prefix,
        "blocks": blocks,
    }


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the cache — zero allocation."""
    return jax.eval_shape(
        partial(init_cache, cfg, batch, max_len, dtype))
