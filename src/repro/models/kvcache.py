"""Decode-time cache construction: zeros + specs (via eval_shape, no alloc).

Two cache layouts coexist:

**Contiguous** (the PR-1..3 layout) mirrors the scanned block structure:
  {"pos": (B,) int32,
   "prefix": (per prefix layer dict,),
   "blocks": (per pattern-position dict, leaves stacked over n_blocks)}
Attention layers use a ring buffer of length ``cache_window`` (= sliding
window for local layers); recurrent mixers carry O(1) state. Every slot
owns ``max_len`` rows up front — KV memory is provisioned for the worst
case.

**Paged** (vLLM-style) replaces the per-slot ring buffers with a shared
pool of fixed-size pages:
  {"pos": (B,) int32,
   "page_table": (B, P) int32        # logical page -> physical page id
   "kv_pos":     (N, page) int32     # shared across layers (-1 = unfilled)
   "prefix": (per layer {"k","v"} pools,),
   "blocks": (stacked {"k","v"} pools,)}
where every attention layer's k/v pool is ``(N, page, K, hd)``. Page id 0
is a reserved **null page** that is never allocated: unassigned page-table
entries point at it, its ``kv_pos`` rows stay -1 forever, so gathers
through unallocated entries are masked rather than garbage (the same trick
the flash-decode kernel's DMA-eliding clamp relies on). There is no ring
wrap in paged mode — logical row == absolute position — which is
token-identical to the contiguous path because a ring only ever overwrites
positions the sliding-window mask has already excluded.

Physical pages are handed out by the host-side :class:`PageAllocator`
(free-list alloc on admission/growth, release on retirement); KV memory
scales with the tokens actually resident, not ``slots * max_len``.
Paging supports attention-family mixers only (:func:`supports_paging`).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contracts_enabled
from repro.models.attention import cache_window

PAGEABLE_MIXERS = ("attn", "attn_local", "attn_global")


def _layer_cache(cfg, spec, batch, max_len, dtype):
    B = batch
    mixer = spec.mixer
    if mixer in ("attn", "attn_local", "attn_global"):
        W = cache_window(cfg, mixer, max_len)
        c = {
            "k": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "kv_pos": jnp.full((B, W), -1, jnp.int32),
        }
        if cfg.family == "encdec":
            c["ck"] = jnp.zeros((B, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["cv"] = jnp.zeros((B, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["c_len"] = jnp.zeros((B,), jnp.int32)  # valid encoder length
        return c
    if mixer == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((B, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((B, max_len, m.qk_rope_head_dim), dtype),
            "kv_pos": jnp.full((B, max_len), -1, jnp.int32),
        }
    if mixer == "mamba":
        mc = cfg.mamba
        d_in = mc.expand * cfg.d_model
        return {
            "ssm": jnp.zeros((B, d_in, mc.d_state), jnp.float32),
            "conv": jnp.zeros((B, mc.d_conv - 1, d_in), dtype),
        }
    if mixer == "mlstm":
        xc = cfg.xlstm
        d_in = int(xc.mlstm_proj_factor * cfg.d_model)
        dk = d_in // xc.num_heads
        return {
            "C": jnp.zeros((B, xc.num_heads, dk, dk), jnp.float32),
            "n": jnp.zeros((B, xc.num_heads, dk), jnp.float32),
            "m": jnp.zeros((B, xc.num_heads), jnp.float32),
            "conv": jnp.zeros((B, xc.conv1d_kernel - 1, d_in), dtype),
        }
    if mixer == "slstm":
        d = cfg.d_model
        return {
            "c": jnp.zeros((B, d), jnp.float32),
            "n": jnp.zeros((B, d), jnp.float32),
            "h": jnp.zeros((B, d), jnp.float32),
            "m": jnp.full((B, d), -1.0e30, jnp.float32),  # matches slstm_forward init
        }
    raise ValueError(mixer)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    prefix_spec = cfg.pattern[0]
    prefix = tuple(
        _layer_cache(cfg, type(prefix_spec)(mixer=prefix_spec.mixer, ffn="dense"),
                     batch, max_len, dtype)
        for _ in range(cfg.first_dense_layers)
    )

    def stack(fn, n):
        leaves = [fn() for _ in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    blocks = tuple(
        stack(partial(_layer_cache, cfg, spec, batch, max_len, dtype),
              cfg.num_blocks)
        for spec in cfg.pattern
    )
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "prefix": prefix,
        "blocks": blocks,
    }


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the cache — zero allocation."""
    return jax.eval_shape(
        partial(init_cache, cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# Paged KV cache
# ---------------------------------------------------------------------------


def supports_paging(cfg) -> bool:
    """True when the paged layout covers every layer's cache: attention-family
    mixers only (MLA / recurrent mixers keep their own state layouts) and no
    encoder/cross-attention side caches."""
    if cfg.family in ("encdec", "vlm"):
        return False
    mixers = {s.mixer for s in cfg.pattern}
    if cfg.first_dense_layers:
        mixers.add(cfg.pattern[0].mixer)
    return mixers <= set(PAGEABLE_MIXERS)


class PageExhausted(RuntimeError):
    """The page pool has no free page for a required allocation."""


def prefix_keys(tokens, page_size: int) -> List[Tuple[int, bytes]]:
    """Chain-hash candidates for cross-request prefix caching.

    Returns ``[(n_rows, key), ...]`` shortest-first: one candidate per full
    page boundary ``k * page_size <= len(tokens) - 1`` plus the maximal
    prefix ``len(tokens) - 1`` when it ends mid-page. The cap at ``len - 1``
    guarantees every request keeps at least one suffix token to run through
    ``extend`` — the forward pass that produces its first-token logits.

    Each key hashes (previous key, this span's tokens), so a key commits to
    the ENTIRE token prefix, not just its last span; the page size is folded
    into the chain root so pools with different page geometry never share
    keys. Registration and lookup both derive candidates from this one
    function — the sets match by construction.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    n = len(toks)
    out: List[Tuple[int, bytes]] = []
    digest = b"kvpage:%d" % page_size
    done = 0
    for b in range(page_size, n, page_size):   # b <= n - 1 by construction
        digest = hashlib.sha256(digest + toks[done:b].tobytes()).digest()
        out.append((b, digest))
        done = b
    if done < n - 1:
        tail = hashlib.sha256(digest + toks[done:n - 1].tobytes()).digest()
        out.append((n - 1, tail))
    return out


@dataclass(frozen=True)
class PrefixEntry:
    """One published prefix: ``pages`` hold rows ``[0, n_rows)`` of every
    request whose prompt starts with the hashed token prefix. A
    maximal (mid-page) entry's last page also holds ONE stale row beyond
    the claim — row ``n_rows``, the publisher's final prompt token. That
    is exactly where a consumer's first suffix write lands, and every
    layer writes its page rows before attending (model.extend), so the
    stale row is overwritten before any read can see it."""

    key: bytes
    n_rows: int
    pages: Tuple[int, ...]


class PageAllocator:
    """Host-side free-list allocator for the paged KV pool.

    Pages ``1..num_pages-1`` are allocatable; page 0 is the reserved null
    page. Each serving slot owns an ordered list of pages covering its
    logical rows ``[0, len)``; :meth:`ensure` grows a slot on demand
    (admission, chunked prefill, decode crossing a page boundary) and
    :meth:`release` returns every page of a retired slot to the free list.

    :meth:`reserve` is the admission-time backpressure primitive of the
    engine's "reserve" policy: it budgets a slot's WORST-CASE page count
    (prompt + max_new rows) against :attr:`pages_available` without
    allocating anything, so later :meth:`ensure` growth — a decode step
    crossing a page boundary, the next prefill chunk — can never exhaust
    the pool mid-request. Physical pages are still handed out lazily;
    reservations are pure accounting. The engine's default "optimistic"
    policy never reserves: it admits against the free list directly and
    answers a failed :meth:`ensure` by preempting a resident slot
    (:meth:`release` both frees the pages and drops any reservation, so
    preemption and retirement share one exit path).

    **Cross-request prefix caching** (``prefix_cache=True``): pages become
    refcounted. :meth:`register_prefix` publishes a slot's freshly written
    prompt pages under chain-hash keys (:func:`prefix_keys`);
    :meth:`match_prefix` finds the longest cached prefix of a new prompt
    and :meth:`splice_prefix` maps its pages into the new slot (incref —
    the pages now back several page tables at once). :meth:`release` then
    decrefs instead of freeing; a page whose refcount reaches zero stays
    RESIDENT while the prefix index still references it, forming an LRU
    cache of warm prefixes that is reclaimed on demand (allocation
    pressure evicts least-recently-matched entries first;
    ``prefix_cache_pages`` caps the resident unreferenced footprint).
    :meth:`cow` re-maps one logical page of a slot to a private copy
    target so a writer never mutates a page another slot or the index
    maps — the engine copies the page payload device-side and then writes
    into the copy. Pages freed by cache eviction are queued on
    :meth:`drain_evicted` so the engine can neutralise their stale
    ``kv_pos`` rows before reuse.

    Invariants (property-tested): refcounts equal the number of slot page
    tables mapping each page; no page is simultaneously free and mapped
    (or free and cached); ``free + mapped + cached-unreferenced ==
    num_pages - 1`` at all times; ``pages_available >= 0``. Without
    prefix caching every refcount is 1 and the original exclusive-
    ownership invariants fall out as the special case.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 prefix_cache: bool = False,
                 prefix_cache_pages: Optional[int] = None):
        if num_pages < 2:
            raise ValueError(f"num_pages ({num_pages}) must be >= 2 "
                             "(page 0 is the reserved null page)")
        if page_size < 1:
            raise ValueError(f"page_size ({page_size}) must be >= 1")
        if prefix_cache_pages is not None and prefix_cache_pages < 0:
            raise ValueError(
                f"prefix_cache_pages ({prefix_cache_pages}) must be >= 0")
        self.num_pages = num_pages
        self.page_size = page_size
        self.prefix_cache = bool(prefix_cache)
        self.prefix_cache_pages = prefix_cache_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}   # slot -> budgeted page count
        self._refs: Dict[int, int] = {}       # page -> # slot tables mapping it
        # prefix index: key -> entry, insertion/touch order == LRU order
        self._prefix: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self._cached: Dict[int, int] = {}     # page -> # index entries using it
        self._evicted: List[int] = []         # freed-by-eviction, undrained
        # monotonic telemetry counters (the engine snapshots them at
        # reset_stats and reports deltas)
        self.evictions = 0                    # prefix entries LRU-dropped
        self.cow_count = 0                    # copy-on-write page copies

    # ------------------------------------------------------------ queries
    @property
    def pages_in_use(self) -> int:
        """UNIQUE pages mapped by at least one resident slot — a page
        shared across page tables counts once (refcount-aware)."""
        return len(self._refs)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_cached(self) -> int:
        """Resident prefix-cache pages mapped by NO slot: warm KV kept
        around for future hits, reclaimable on demand."""
        return sum(1 for p in self._cached if p not in self._refs)

    @property
    def pages_available(self) -> int:
        """Pages an admission can claim right now: the free list plus
        evictable cached pages, minus outstanding reservation debt. A
        reservation is backed only by pages its slot can write WITHOUT a
        copy (exclusively mapped, not in the prefix index), so the budget
        always covers the copy-on-write a shared page may later cost."""
        unbacked = sum(max(r - self._exclusive(s), 0)
                       for s, r in self._reserved.items())
        return len(self._free) + self.pages_cached - unbacked

    def _exclusive(self, slot: int) -> int:
        # A refs-1 page backs its owner's reservation even while the
        # prefix index caches it: if a write ever needs the page back
        # exclusively under exhaustion, evicting the cache entry restores
        # exclusivity without consuming a page (the engine falls back to
        # an in-place write). Only a second MAPPING (refs > 1, i.e. a
        # warm splice) truly un-backs it — and splice budgets for that.
        return sum(1 for p in self._owned.get(slot, ())
                   if self._refs.get(p, 0) == 1)

    def pages_for(self, n_rows: int) -> int:
        """Pages needed to hold ``n_rows`` logical rows."""
        return -(-max(n_rows, 0) // self.page_size)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def reserved(self, slot: int) -> int:
        """The slot's budgeted page count (0 if nothing reserved)."""
        return self._reserved.get(slot, 0)

    def refs(self, page: int) -> int:
        """How many slot page tables map ``page`` right now."""
        return self._refs.get(page, 0)

    def page_shared(self, page: int) -> bool:
        """True when writing ``page`` in place would corrupt state some
        other reader depends on: another slot maps it, or the prefix
        index holds it for future requests. Writers must :meth:`cow`."""
        return self._refs.get(page, 0) > 1 or page in self._cached

    @property
    def prefix_entries(self) -> int:
        return len(self._prefix)

    # ---------------------------------------------------------- mutation
    def reserve(self, slot: int, n_rows: int):
        """Budget pages so ``slot`` can grow to ``n_rows`` rows without
        ever failing an :meth:`ensure` (or a COW). Raises
        :class:`PageExhausted` — with nothing recorded — if the
        unreserved pool cannot cover it."""
        need = self.pages_for(n_rows)
        backed = max(self._reserved.get(slot, 0), self._exclusive(slot))
        grow = need - backed
        if grow <= 0:
            return
        if grow > self.pages_available:
            raise PageExhausted(
                f"slot {slot} needs a budget of {need} page(s) for "
                f"{n_rows} rows but only {self.pages_available} of "
                f"{self.num_pages - 1} are unreserved (raise kv_pages or "
                "admit fewer requests)")
        self._reserved[slot] = need

    def _evict_lru(self) -> None:
        """Drop the least-recently-matched prefix entry; its pages return
        to the free list once nothing else references them."""
        _, entry = self._prefix.popitem(last=False)
        self.evictions += 1
        for p in entry.pages:
            left = self._cached[p] - 1
            if left:
                self._cached[p] = left
                continue
            del self._cached[p]
            if p not in self._refs:
                self._free.append(p)
                self._evicted.append(p)

    def _take_free(self, n: int) -> List[int]:
        """Pop ``n`` pages off the free list, reclaiming LRU cache entries
        as needed. Raises :class:`PageExhausted` with nothing allocated
        (already-triggered evictions stand — they only grew the free
        list) when even a fully drained cache cannot cover it."""
        while len(self._free) < n and self._prefix:
            self._evict_lru()
        if len(self._free) < n:
            raise PageExhausted(
                f"need {n} page(s) but only {len(self._free)} of "
                f"{self.num_pages - 1} are free (raise kv_pages or shrink "
                "the admitted batch)")
        return [self._free.pop() for _ in range(n)]

    def _trim_cache(self) -> None:
        if self.prefix_cache_pages is None:
            return
        while self._prefix and self.pages_cached > self.prefix_cache_pages:
            self._evict_lru()

    def ensure(self, slot: int, n_rows: int) -> List[int]:
        """Grow ``slot`` to cover rows ``[0, n_rows)``; returns the newly
        allocated page ids (empty if already covered). Raises
        :class:`PageExhausted` — with the slot untouched — if the pool
        (free list + evictable cached pages) cannot satisfy the growth."""
        have = self._owned.setdefault(slot, [])
        need = self.pages_for(n_rows) - len(have)
        if need <= 0:
            return []
        if need > len(self._free) + self.pages_cached:
            raise PageExhausted(
                f"slot {slot} needs {need} more page(s) for {n_rows} rows "
                f"but only {len(self._free) + self.pages_cached} of "
                f"{self.num_pages - 1} are free (raise kv_pages or shrink "
                "the admitted batch)")
        fresh = self._take_free(need)
        for p in fresh:
            self._refs[p] = 1
        have.extend(fresh)
        if contracts_enabled():
            self._check_invariants()
        return fresh

    def release(self, slot: int) -> List[int]:
        """Decref every page of ``slot`` (and drop its reservation);
        returns the page ids actually FREED — shared pages survive under
        their other owners, and pages the prefix index references stay
        resident as reclaimable cache. Retirement and preemption share
        this one exit path, so preempting a warm-prefix request can never
        free pages another request still maps."""
        self._reserved.pop(slot, None)
        freed: List[int] = []
        for p in self._owned.pop(slot, []):
            left = self._refs[p] - 1
            if left:
                self._refs[p] = left
                continue
            del self._refs[p]
            if p in self._cached:
                continue                      # stays resident for reuse
            self._free.append(p)
            freed.append(p)
        self._trim_cache()
        if contracts_enabled():
            self._check_invariants()
        return freed

    def cow(self, slot: int, logical_page: int) -> Tuple[int, int]:
        """Copy-on-write: re-map ``slot``'s ``logical_page`` from its
        shared physical page to a freshly allocated private one. Returns
        ``(old, new)``; the caller must copy the page payload (and its
        ``kv_pos`` row) device-side before writing. Raises
        :class:`PageExhausted` with the mapping untouched when no page
        can be claimed."""
        owned = self._owned[slot]
        old = owned[logical_page]
        new = self._take_free(1)[0]
        self.cow_count += 1
        self._refs[new] = 1
        left = self._refs[old] - 1
        if left:
            self._refs[old] = left
        elif old in self._cached:
            del self._refs[old]               # lives on as cache only
        else:
            # sole owner and the caching entry was evicted while claiming
            # the copy target: the old page is plain free after the swap
            del self._refs[old]
            self._free.append(old)
            self._evicted.append(old)
        owned[logical_page] = new
        if contracts_enabled():
            self._check_invariants()
        return old, new

    # ----------------------------------------------------- prefix caching
    def match_prefix(self, candidates: Sequence[Tuple[int, bytes]],
                     *, touch: bool = True) -> Optional[PrefixEntry]:
        """Longest cached prefix among ``candidates`` (``prefix_keys``
        output), or None. ``touch`` refreshes the winner's LRU position —
        pass False for scheduling probes that may not lead to admission."""
        if not self.prefix_cache:
            return None
        for n_rows, key in sorted(candidates, key=lambda c: c[0],
                                  reverse=True):
            entry = self._prefix.get(key)
            if entry is not None and entry.n_rows == n_rows:
                if touch:
                    self._prefix.move_to_end(key)
                return entry
        return None

    def splice_prefix(self, slot: int, entry: PrefixEntry) -> List[int]:
        """Map a cached prefix's pages into fresh ``slot`` (incref each);
        the slot's logical rows ``[0, entry.n_rows)`` are now backed by
        shared physical pages and need no prefill."""
        if self._owned.get(slot):
            raise ValueError(
                f"splice_prefix into slot {slot} which already owns pages")
        pages = list(entry.pages)
        for p in pages:
            self._refs[p] = self._refs.get(p, 0) + 1
        self._owned[slot] = pages
        self._prefix.move_to_end(entry.key)
        if contracts_enabled():
            self._check_invariants()
        return pages

    def register_prefix(self, slot: int,
                        candidates: Sequence[Tuple[int, bytes]]) -> int:
        """Publish ``slot``'s freshly written prefix pages under every
        candidate key (``prefix_keys`` of the tokens just prefilled).
        Existing entries are touched, new ones map the slot's leading
        pages. Returns the number of entries added."""
        if not self.prefix_cache:
            return 0
        owned = self._owned.get(slot, [])
        added = 0
        for n_rows, key in sorted(candidates, key=lambda c: c[0]):
            if key in self._prefix:
                self._prefix.move_to_end(key)
                continue
            n_pages = self.pages_for(n_rows)
            if n_pages > len(owned):
                continue                      # slot doesn't cover this span
            pages = tuple(owned[:n_pages])
            self._prefix[key] = PrefixEntry(key=key, n_rows=n_rows,
                                            pages=pages)
            for p in pages:
                self._cached[p] = self._cached.get(p, 0) + 1
            added += 1
        self._trim_cache()
        if contracts_enabled():
            self._check_invariants()
        return added

    def drain_evicted(self) -> List[int]:
        """Pages returned to the free list by cache eviction since the
        last drain. The engine resets their stale ``kv_pos`` rows before
        the pages can be re-issued to a new owner."""
        evicted, self._evicted = self._evicted, []
        return evicted

    def _check_invariants(self) -> None:
        """The property-tested allocator invariants, asserted inline under
        REPRO_CONTRACTS (tests/CI); never called in production."""
        mult: Dict[int, int] = {}
        for pages in self._owned.values():
            assert len(pages) == len(set(pages)), (
                "slot maps a physical page twice")
            for p in pages:
                mult[p] = mult.get(p, 0) + 1
        assert mult == self._refs, (
            "refcounts out of sync with slot page tables")
        assert 0 not in mult and 0 not in self._free \
            and 0 not in self._cached, "null page 0 entered circulation"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "page double-freed"
        assert not free_set & set(mult), "page both free and mapped"
        assert not free_set & set(self._cached), "page both free and cached"
        cached_only = sum(1 for p in self._cached if p not in mult)
        assert len(self._free) + len(mult) + cached_only \
            == self.num_pages - 1, (
            f"page leak: {len(self._free)} free + {len(mult)} mapped + "
            f"{cached_only} cached != {self.num_pages - 1}")
        for entry in self._prefix.values():
            assert len(entry.pages) == self.pages_for(entry.n_rows), (
                "prefix entry page count != pages_for(n_rows)")
            for p in entry.pages:
                assert self._cached.get(p, 0) >= 1, (
                    "prefix entry references an untracked page")
        assert self.pages_available >= 0, "reservations exceed the pool"

    def table_row(self, slot: int, table_len: int):
        """The slot's page table row, null-padded to ``table_len``."""
        row = np.zeros((table_len,), np.int32)
        pages = self._owned.get(slot, ())
        row[:len(pages)] = pages
        return row


def _attn_layer_counts(cfg) -> int:
    """Number of attention-layer caches (prefix + per-block pattern slots)."""
    return cfg.first_dense_layers + cfg.num_blocks * len(cfg.pattern)


def init_paged_cache(cfg, batch: int, max_len: int, *, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    """Device-side paged cache pytree (see module docstring for layout)."""
    if not supports_paging(cfg):
        raise ValueError(
            f"{cfg.name}: paged KV requires attention-family mixers only")
    if max_len % page_size:
        raise ValueError(f"max_len ({max_len}) must be a multiple of "
                         f"kv_page_size ({page_size})")
    K, hd = cfg.num_kv_heads, cfg.head_dim
    P = max_len // page_size

    def pool():
        return {
            "k": jnp.zeros((num_pages, page_size, K, hd), dtype),
            "v": jnp.zeros((num_pages, page_size, K, hd), dtype),
        }

    prefix = tuple(pool() for _ in range(cfg.first_dense_layers))
    blocks = tuple(
        jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[pool() for _ in range(cfg.num_blocks)])
        for _ in cfg.pattern
    )
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "page_table": jnp.zeros((batch, P), jnp.int32),
        "kv_pos": jnp.full((num_pages, page_size), -1, jnp.int32),
        "prefix": prefix,
        "blocks": blocks,
    }


def paged_cache_specs(cfg, batch: int, max_len: int, *, num_pages: int,
                      page_size: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the paged cache — zero allocation. The
    paged analogue of :func:`cache_specs`, used to derive shardings for the
    engine's jitted extend/decode path under a mesh."""
    return jax.eval_shape(
        partial(init_paged_cache, cfg, batch, max_len, num_pages=num_pages,
                page_size=page_size, dtype=dtype))


def paged_write_coords(page_table, pos, n_tokens: int, page_size: int,
                       valid):
    """Flat pool-row indices for writing ``n_tokens`` rows per slot starting
    at ``pos``. Rows at or beyond ``valid[b]`` are redirected to flat index 0
    (null page, row 0) so dead slots / tail padding never corrupt live pages;
    their ``kv_pos`` value is -1. Returns (flat (B, C) int32 into the
    ``(N * page,)``-flattened pool, positions (B, C), kv_pos_vals (B, C))."""
    C = n_tokens
    offs = pos[:, None].astype(jnp.int32) + jnp.arange(C, dtype=jnp.int32)
    P = page_table.shape[1]
    pi = jnp.clip(offs // page_size, 0, P - 1)
    phys = jnp.take_along_axis(page_table, pi, axis=1)
    flat = phys * page_size + offs % page_size
    ok = jnp.arange(C, dtype=jnp.int32)[None] < valid[:, None]
    return (jnp.where(ok, flat, 0), offs,
            jnp.where(ok, offs, jnp.int32(-1)))


def gather_paged_kv(pool, page_table):
    """Materialise the logical view of a paged pool for the jnp backend:
    pool (N, page, ...) gathered by page_table (B, P) -> (B, P*page, ...).
    Unallocated entries gather the null page (kv_pos -1 -> masked)."""
    g = jnp.take(pool, page_table, axis=0)  # (B, P, page, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_kv_page_bytes(cfg, page_size: int) -> int:
    """HBM bytes one physical page costs across every attention layer
    (k + v pools) plus its shared kv_pos rows."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    kv = 2 * page_size * cfg.num_kv_heads * cfg.head_dim * itemsize
    return _attn_layer_counts(cfg) * kv + page_size * 4  # + int32 kv_pos


def contiguous_kv_bytes(cfg, batch: int, max_len: int) -> int:
    """What the contiguous layout provisions up front: every slot owns a
    ``cache_window``-row ring (+ kv_pos) in every attention layer."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    total = 0
    specs = [cfg.pattern[0].mixer] * cfg.first_dense_layers + \
        [s.mixer for s in cfg.pattern] * cfg.num_blocks
    for mixer in specs:
        W = cache_window(cfg, mixer, max_len)
        total += batch * W * (
            2 * cfg.num_kv_heads * cfg.head_dim * itemsize + 4)
    return total
