"""Trace-time mode flags.

``cost_accurate_mode``: XLA's HloCostAnalysis counts a while-loop body ONCE
regardless of trip count, so a rolled ``lax.scan`` under-reports FLOPs,
bytes, and collective traffic by the trip count. The dry-run therefore
compiles each cell twice:

  * rolled (production artifact)  -> memory_analysis (structurally accurate:
    buffers are explicitly reused across iterations)
  * cost-accurate (this flag on)  -> cost_analysis + collective parse: every
    scan (outer block scan AND inner chunk scans — attention q-chunks,
    chunked CE, mamba/mLSTM chunk scans) runs with 4 unrolled mega-chunks so
    each op is materialised in the HLO exactly as many times as it executes.

Flag is read at trace time; never enabled during real execution.
"""
from __future__ import annotations

import contextlib

COST_ACCURATE = False
_INNER_CHUNKS = 4


@contextlib.contextmanager
def cost_accurate_mode(on: bool = True):
    global COST_ACCURATE
    old = COST_ACCURATE
    COST_ACCURATE = on
    try:
        yield
    finally:
        COST_ACCURATE = old


def chunking(seq_len: int, default_chunk: int):
    """(chunk_size, unroll) for an inner sequence-chunk scan."""
    if COST_ACCURATE:
        if seq_len % _INNER_CHUNKS == 0 and seq_len >= _INNER_CHUNKS:
            return seq_len // _INNER_CHUNKS, True
        return seq_len, True
    return default_chunk, False
