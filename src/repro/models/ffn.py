"""Dense gated FFN (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation, dense_init


def init_ffn(key, d: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    dtype = jnp.dtype(dtype)
    return {
        "wg": dense_init(kg, (d, d_ff), dtype),
        "wu": dense_init(ku, (d, d_ff), dtype),
        "wd": dense_init(kd, (d_ff, d), dtype),
    }


def ffn_forward(params, x, act: str = "silu"):
    f = activation(act)
    return (f(x @ params["wg"]) * (x @ params["wu"])) @ params["wd"]
