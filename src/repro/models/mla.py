"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Training / prefill use the expanded form. Decode uses the *absorbed* form:
queries are projected into the kv_lora latent space so attention runs
directly against the compressed cache (kv_lora + rope dims per token), which
is the memory-saving mechanism that makes a 500-token-wide 128-head model
decodable — the cache is (B, L, 576) instead of (B, L, 128, 256).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, init_rms_norm, rms_norm

NEG_INF = -2.0e38


def init_mla(key, cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, H * m.qk_head_dim), dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "w_kr": dense_init(ks[3], (d, m.qk_rope_head_dim), dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "w_o": dense_init(ks[6], (H * m.v_head_dim, d), dtype),
        "q_norm": init_rms_norm(m.q_lora_rank),
        "kv_norm": init_rms_norm(m.kv_lora_rank),
    }


def _queries(params, cfg, x, positions):
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    c_q = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
    q = (c_q @ params["w_uq"]).reshape(B, S, H, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compressed_kv(params, cfg, x, positions):
    m = cfg.mla
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    k_rope = (x @ params["w_kr"])[:, :, None, :]  # shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(params, cfg, x, positions, *, q_chunk: int = 1024,
                return_kv: bool = False):
    """Expanded-form MLA for train/prefill. x: (B,S,d)."""
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _compressed_kv(params, cfg, x, positions)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    scale = 1.0 / (m.qk_head_dim ** 0.5)

    # k_rope is a single shared head on the K side, per-head on the Q side;
    # the s_rope einsum broadcasts it across heads.
    def chunk_body_full(_, args):
        qn, qr, qpos = args  # (B,C,H,dn), (B,C,H,dr), (B,C)
        s_nope = jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", qr, k_rope)
        logits = (s_nope + s_rope).astype(jnp.float32) * scale
        mask = (qpos[:, :, None] >= positions[:, None, :])[:, None]
        logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", w, v)

    from repro.models.flags import chunking

    q_chunk, unroll_inner = chunking(S, q_chunk)
    if S > q_chunk and S % q_chunk == 0:
        n = S // q_chunk
        qn = q_nope.reshape(B, n, q_chunk, H, -1).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, n, q_chunk, H, -1).transpose(1, 0, 2, 3, 4)
        ps = positions.reshape(B, n, q_chunk).transpose(1, 0, 2)
        _, outs = jax.lax.scan(
            jax.checkpoint(chunk_body_full, prevent_cse=unroll_inner), None,
            (qn, qr, ps), unroll=n if unroll_inner else 1)
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, m.v_head_dim)
    else:
        _, out = chunk_body_full(None, (q_nope, q_rope, positions))

    out = out.reshape(B, S, H * m.v_head_dim) @ params["w_o"]
    if return_kv:
        return out, (c_kv, k_rope)
    return out, None


def mla_decode(params, cfg, x, pos, cache_layer):
    """Absorbed-form decode against the compressed cache.

    cache_layer: {"c_kv": (B, L, kv_lora), "k_rope": (B, L, rope),
                  "kv_pos": (B, L)}.
    """
    m, H = cfg.mla, cfg.num_heads
    B = x.shape[0]
    q_nope, q_rope = _queries(params, cfg, x, pos[:, None])  # (B,1,H,*)
    c_kv_new, k_rope_new = _compressed_kv(params, cfg, x, pos[:, None])

    L = cache_layer["c_kv"].shape[1]
    slot = (pos % L).astype(jnp.int32)
    upd2 = jax.vmap(lambda b, n, s: jax.lax.dynamic_update_slice(b, n, (s, 0)))
    c_kv = upd2(cache_layer["c_kv"], c_kv_new, slot)
    k_rope = upd2(cache_layer["k_rope"], k_rope_new, slot)
    kv_pos = jax.vmap(lambda p, s, val: jax.lax.dynamic_update_slice(p, val, (s,)))(
        cache_layer["kv_pos"], slot, pos[:, None].astype(jnp.int32))

    # absorb W_uk into the query: q_lat (B,H,kv_lora)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk)
    scale = 1.0 / (m.qk_head_dim ** 0.5)

    s_nope = jnp.einsum("bhl,btl->bht", q_lat, c_kv)
    s_rope = jnp.einsum("bhd,btd->bht", q_rope[:, 0], k_rope)
    logits = (s_nope + s_rope).astype(jnp.float32) * scale
    mask = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)

    o_lat = jnp.einsum("bht,btl->bhl", w, c_kv)  # (B,H,kv_lora)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhl,lhd->bhd", o_lat, w_uv).reshape(B, 1, H * m.v_head_dim)
    out = o @ params["w_o"]
    return out, {"c_kv": c_kv, "k_rope": k_rope, "kv_pos": kv_pos}


def mla_fill_cache_from_prefill(cfg, c_kv, k_rope, positions, max_len: int):
    B, S, _ = c_kv.shape
    take = min(S, max_len)
    buf_c = jnp.zeros((B, max_len, cfg.mla.kv_lora_rank), c_kv.dtype)
    buf_r = jnp.zeros((B, max_len, cfg.mla.qk_rope_head_dim), k_rope.dtype)
    kv_pos = jnp.full((B, max_len), -1, jnp.int32)
    pos_tail = positions[:, S - take:]
    slots = pos_tail % max_len
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], slots.shape)
    buf_c = buf_c.at[bidx, slots].set(c_kv[:, S - take:])
    buf_r = buf_r.at[bidx, slots].set(k_rope[:, S - take:])
    kv_pos = kv_pos.at[bidx, slots].set(pos_tail)
    return {"c_kv": buf_c, "k_rope": buf_r, "kv_pos": kv_pos}
