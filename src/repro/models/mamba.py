"""Mamba-1 selective SSM block (Jamba-style), TPU-adapted.

Hardware adaptation (DESIGN.md §3): the CUDA reference fuses the selective
scan in SM shared memory. On TPU we use a *chunked* parallel scan: a
``lax.scan`` over chunks of length ``CHUNK`` carrying the (B, d_in, d_state)
SSM state, with a ``lax.associative_scan`` inside each chunk. The transient
(B, CHUNK, d_in, N) tensor is what a Pallas fusion would keep in VMEM; chunk
size is chosen so it stays ~tens of MB per device under TP sharding of d_in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

CHUNK = 128


def _dt_rank(cfg) -> int:
    return cfg.mamba.dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg) -> dict:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    # S4-style A initialisation: -[1..N] per channel
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None], (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (mc.d_conv, d_in), dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dtr + 2 * mc.d_state), dtype),
        "dt_proj_w": dense_init(ks[3], (dtr, d_in), dtype),
        "dt_proj_b": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(a),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d), dtype),
    }


def _ssm_scan_chunked(u, dt, Bmat, Cmat, A, h0):
    """Selective scan. u,dt: (B,S,d_in); Bmat,Cmat: (B,S,N); A: (d_in,N).

    Returns y (B,S,d_in) and final state (B,d_in,N).
    """
    from repro.models.flags import chunking

    Bb, S, d_in = u.shape
    N = A.shape[1]
    chunk, unroll_inner = chunking(S, CHUNK)
    n_chunks = max(1, S // chunk)
    c = S // n_chunks

    def chunk_body(h, args):
        uc, dtc, bc, cc = args  # (B,c,d_in), (B,c,d_in), (B,c,N), (B,c,N)
        dA = jnp.exp(dtc[..., None] * (-jnp.exp(A))[None, None])  # (B,c,d_in,N)
        dBu = (dtc * uc)[..., None] * bc[:, :, None, :]            # (B,c,d_in,N)

        def combine(a, b):
            (ga, xa), (gb, xb) = a, b
            return ga * gb, xa * gb + xb

        gates, states = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
        states = states + gates * h[:, None]  # fold in carry state
        y = jnp.einsum("bcdn,bcn->bcd", states, cc)
        return states[:, -1], y

    u_c = u.reshape(Bb, n_chunks, c, d_in).transpose(1, 0, 2, 3)
    dt_c = dt.reshape(Bb, n_chunks, c, d_in).transpose(1, 0, 2, 3)
    b_c = Bmat.reshape(Bb, n_chunks, c, N).transpose(1, 0, 2, 3)
    c_c = Cmat.reshape(Bb, n_chunks, c, N).transpose(1, 0, 2, 3)
    h_last, ys = jax.lax.scan(
        jax.checkpoint(chunk_body, prevent_cse=unroll_inner), h0,
        (u_c, dt_c, b_c, c_c), unroll=n_chunks if unroll_inner else 1)
    y = ys.transpose(1, 0, 2, 3).reshape(Bb, S, d_in)
    return y, h_last


def mamba_forward(params, cfg, x, *, return_state: bool = False):
    """x: (B, S, d). Causal conv + selective SSM + gate."""
    mc = cfg.mamba
    B, S, d = x.shape
    d_in = mc.expand * d
    dtr = _dt_rank(cfg)

    xz = x @ params["in_proj"]
    u, z = xz[..., :d_in], xz[..., d_in:]

    # causal depthwise conv along seq
    pad = mc.d_conv - 1
    u_pad = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
    windows = jnp.stack([u_pad[:, i:i + S] for i in range(mc.d_conv)], axis=-1)
    u = jnp.einsum("bsdk,kd->bsd", windows, params["conv_w"]) + params["conv_b"]
    u = jax.nn.silu(u)

    proj = u @ params["x_proj"]  # (B,S,dtr+2N)
    dt = jax.nn.softplus(
        proj[..., :dtr] @ params["dt_proj_w"] + params["dt_proj_b"]).astype(jnp.float32)
    Bmat = proj[..., dtr:dtr + mc.d_state].astype(jnp.float32)
    Cmat = proj[..., dtr + mc.d_state:].astype(jnp.float32)

    h0 = jnp.zeros((B, d_in, mc.d_state), jnp.float32)
    y, h_last = _ssm_scan_chunked(u.astype(jnp.float32), dt, Bmat, Cmat,
                                  params["A_log"], h0)
    y = y + u.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        # last (d_conv-1) raw pre-conv inputs, for streaming decode
        conv_state = (u_pad[:, S:S + pad] if pad
                      else jnp.zeros((B, 0, d_in), x.dtype))
        return out, {"ssm": h_last, "conv": conv_state}
    return out, None


def mamba_decode(params, cfg, x, cache_layer):
    """Single-step decode. x: (B, 1, d).

    cache_layer: {"ssm": (B, d_in, N) fp32, "conv": (B, d_conv-1, d_in)}.
    """
    mc = cfg.mamba
    B = x.shape[0]
    d_in = mc.expand * cfg.d_model
    dtr = _dt_rank(cfg)

    xz = x[:, 0] @ params["in_proj"]  # (B, 2*d_in)
    u_new, z = xz[:, :d_in], xz[:, d_in:]

    conv_buf = jnp.concatenate([cache_layer["conv"], u_new[:, None]], axis=1)
    u = jnp.einsum("bkd,kd->bd", conv_buf, params["conv_w"]) + params["conv_b"]
    u = jax.nn.silu(u)
    new_conv = conv_buf[:, 1:]

    proj = u @ params["x_proj"]
    dt = jax.nn.softplus(
        proj[:, :dtr] @ params["dt_proj_w"] + params["dt_proj_b"]).astype(jnp.float32)
    Bmat = proj[:, dtr:dtr + mc.d_state].astype(jnp.float32)
    Cmat = proj[:, dtr + mc.d_state:].astype(jnp.float32)

    uf = u.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * (-jnp.exp(params["A_log"]))[None])  # (B,d_in,N)
    h = cache_layer["ssm"] * dA + (dt * uf)[..., None] * Bmat[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cmat) + uf * params["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return out, {"ssm": h, "conv": new_conv}
