"""Grouped-query attention with full / sliding-window / bidirectional / cross
variants, logit soft-capping, RoPE, and a ring-buffered KV cache for decode.

Prefill & training use q-chunked (memory-efficient) attention: a
``lax.scan`` over query chunks with a rematted chunk body, so neither the
forward nor the backward pass ever materialises the full (S, S) logit matrix.

GQA is never expanded: ``_attend`` contracts q reshaped to (B, S, K, H/K,
hd) against the K-head K/V directly, so neither prefill nor the per-step
decode path materialises an (.., H, hd) K/V copy.

``cfg.attn_impl`` selects the compute backend (mirroring the MoE ``mode=``
convention): "jnp" is the grouped-einsum path everywhere; "pallas" routes
every decode step through the length-aware split-KV flash-decode kernel
(:mod:`repro.kernels.flash_decode` — ring-buffer ``kv_pos`` masking,
sliding window, and logit softcap fused in-kernel) and eligible prefill
layers (causal or sliding-window self-attention, softcap fused — positions
are ``arange(S)`` on every such call in this codebase) through the blocked
flash-attention kernel. Only cross-attention falls back to jnp. The pallas
backend is inference-only: the kernels define no VJP.

Under a mesh, every pallas launch goes through the per-shard ``shard_map``
wrappers in :mod:`repro.kernels.partition` (pass ``pc=``); the jnp path
needs no such routing — GSPMD partitions the einsums directly.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, softcap

NEG_INF = -2.0e38


class AttnParams(NamedTuple):
    wq: jax.Array  # (d, H*hd)
    wk: jax.Array  # (d, K*hd)
    wv: jax.Array  # (d, K*hd)
    wo: jax.Array  # (H*hd, d)


def init_attention(key, cfg) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    return {
        "wq": dense_init(kq, (d, cfg.q_dim), dtype),
        "wk": dense_init(kk, (d, cfg.kv_dim), dtype),
        "wv": dense_init(kv, (d, cfg.kv_dim), dtype),
        "wo": dense_init(ko, (cfg.q_dim, d), dtype),
    }


def _expand_kv(k, num_heads):
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each kv head.

    Kept only as a reference/debug helper — the forward paths contract
    grouped q against un-expanded K/V (see ``_attend``)."""
    B, S, K, hd = k.shape
    rep = num_heads // K
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def _attend(q, k, v, mask, scale, logit_cap):
    """q: (B,Sq,H,hd); k,v: (B,Skv,K,hd) with K | H (un-expanded GQA — each
    kv head serves H/K query heads); mask: (B,Sq,Skv) or (Sq,Skv) bool."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    qg = q.reshape(B, Sq, K, H // K, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    logits = softcap(logits, logit_cap)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", weights, v)
    return out.reshape(B, Sq, H, hd)


def _chunked_attend(q, k, v, mask_fn, q_positions, kv_positions, scale,
                    logit_cap, chunk: int, unroll: bool = False):
    """Scan over query chunks; chunk body is rematted so backward never holds
    more than one chunk of logits."""
    B, S, H, hd = q.shape

    def body(_, args):
        qc, qpos = args  # (B, C, H, hd), (B, C)
        mask = mask_fn(qpos, kv_positions)  # (B, C, Skv)
        out = _attend(qc, k, v, mask, scale, logit_cap)
        return None, out

    n_chunks = S // chunk
    qs = q.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ps = q_positions.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    _, outs = jax.lax.scan(jax.checkpoint(body, prevent_cse=unroll), None,
                           (qs, ps), unroll=n_chunks if unroll else 1)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def make_mask_fn(kind: str, window: int = 0):
    """Returns mask_fn(q_pos (B,Sq), kv_pos (B,Skv)) -> bool (B,Sq,Skv).

    kv_pos entries of -1 mark unfilled cache slots.
    """

    def mask_fn(q_pos, kv_pos):
        q = q_pos[:, :, None]
        kv = kv_pos[:, None, :]
        filled = kv >= 0
        if kind == "causal":
            m = (kv <= q) & filled
        elif kind == "local":
            m = (kv <= q) & (q - kv < window) & filled
        elif kind == "full":  # bidirectional (encoder) / cross-attention
            m = jnp.broadcast_to(filled, (q_pos.shape[0], q_pos.shape[1], kv_pos.shape[1]))
        else:
            raise ValueError(kind)
        return m

    return mask_fn


def attention_forward(params, cfg, spec_mixer: str, x, positions,
                      *, kv_override: Optional[jax.Array] = None,
                      mask_kind: str = "causal",
                      return_kv: bool = False,
                      q_chunk: int = 1024, pc=None):
    """Training / prefill attention.

    x: (B, S, d); positions: (B, S) absolute positions.
    kv_override: encoder output for cross-attention (B, S_src, d).
    pc: ParallelConfig — partitions the pallas launches per-shard under a
    context mesh (repro.kernels.partition); ignored on the jnp path, where
    GSPMD partitions the einsums itself.
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    local = spec_mixer == "attn_local"
    if local:
        mask_kind = "local"

    q = (x @ params["wq"]).reshape(B, S, H, hd)
    kv_src = kv_override if kv_override is not None else x
    Skv = kv_src.shape[1]
    k = (kv_src @ params["wk"]).reshape(B, Skv, K, hd)
    v = (kv_src @ params["wv"]).reshape(B, Skv, K, hd)

    is_cross = kv_override is not None
    if not is_cross:  # rope on self-attention only
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kv_positions = positions
    else:
        kv_positions = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
        mask_kind = "full"

    scale = cfg.attn_scale or 1.0 / (hd ** 0.5)
    mask_fn = make_mask_fn(mask_kind, cfg.sliding_window)

    # pallas prefill path: blocked flash attention for causal and
    # sliding-window self-attention, with tanh softcap fused in-kernel (so
    # gemma2-style layers no longer fall back to jnp). The kernel masks by
    # tile ROW INDEX, which equals the positions-based masks whenever each
    # row's positions ascend by 1 (q_pos >= k_pos <=> i >= j; a shared base
    # offset cancels — likewise for the window band). That holds for every
    # self-attention call in this codebase (model._decoder_inputs builds
    # arange(S)). It does NOT hold for packed sequences with position
    # resets — such a caller must keep attn_impl="jnp" or extend the kernel
    # with explicit positions. Inference-only — no VJP.
    use_flash = (cfg.attn_impl == "pallas" and not is_cross
                 and (mask_kind == "causal"
                      or (mask_kind == "local" and cfg.sliding_window)))
    if use_flash:
        from repro.kernels.partition import sharded_flash_attention

        window = cfg.sliding_window if mask_kind == "local" else 0
        out = sharded_flash_attention(
            cfg, pc, q, k, v, causal=True, scale=scale, window=window,
            logit_cap=cfg.attn_logit_softcap)
        out = out.reshape(B, S, H * hd) @ params["wo"]
        if return_kv:
            return out, (k, v)
        return out, None

    from repro.models.flags import chunking

    q_chunk, unroll_inner = chunking(S, q_chunk)
    if S > q_chunk and S % q_chunk == 0:
        out = _chunked_attend(q, k, v, mask_fn, positions, kv_positions,
                              scale, cfg.attn_logit_softcap, q_chunk,
                              unroll=unroll_inner)
    else:
        mask = mask_fn(positions, kv_positions)
        out = _attend(q, k, v, mask, scale, cfg.attn_logit_softcap)

    out = out.reshape(B, S, H * hd) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out, None


def _decode_kv_sharding(cfg, pc):
    """The resting sharding of a decode-step K/V ring slice (B, W, K, hd)
    under the ambient mesh (:func:`~repro.parallel.sharding.choose_kv_spec`),
    or None outside a >1-way tensor-parallel mesh context."""
    if pc is None:
        return None
    from repro.parallel.sharding import get_context_mesh

    mesh = get_context_mesh()
    if mesh is None or pc.tp_axis not in mesh.shape \
            or int(mesh.shape[pc.tp_axis]) <= 1:
        return None
    from jax.sharding import NamedSharding

    from repro.parallel.sharding import choose_kv_spec

    return NamedSharding(mesh, choose_kv_spec(
        cfg, pc, int(mesh.shape[pc.tp_axis])))


def _pin_kv_sharding(cfg, pc, k_buf, v_buf, q):
    """Pin the freshly-scattered decode K/V ring AND the query to the
    cache's resting sharding. Without the annotations, GSPMD is free to
    pick a different partitioning for the decode attention einsums (it
    favors kv-head×head-group×head_dim tiling) and then cannot reshard
    the vmapped per-slot ``dynamic_update_slice`` output into it — it
    falls back to involuntarily rematerializing the FULL ring on every
    device each step (correct but warned-about and bandwidth-hostile).
    Constraining both einsum operands to the stored layout (kv-heads over
    tp when they divide it, else head_dim) keeps the scatter and the
    attention shard-local; the returned sharding should also be applied
    to the attention OUTPUT (same (B, S, heads, hd) axis order) so the
    layout survives the post-attention transpose into the wo projection.
    Returns (k_buf, v_buf, q, sharding-or-None); no-op outside a >1-way
    tensor-parallel mesh context."""
    sh = _decode_kv_sharding(cfg, pc)
    if sh is None:
        return k_buf, v_buf, q, None
    wsc = jax.lax.with_sharding_constraint
    # q is (B, S=1, H, hd): dims line up with the ring's (B, W, K, hd) for
    # both strategies (heads over tp when K divides it — H = K*G keeps
    # groups shard-local — else head_dim over tp)
    return wsc(k_buf, sh), wsc(v_buf, sh), wsc(q, sh), sh


def decode_attention(params, cfg, spec_mixer: str, x, pos, cache_layer,
                     *, kv_override: Optional[jax.Array] = None, pc=None):
    """Single-token decode with ring-buffered KV cache.

    x: (B, 1, d); pos: (B,) number of tokens already in cache.
    cache_layer: {"k": (B, W, K, hd), "v": ..., "kv_pos": (B, W) int32}.
    pc: ParallelConfig for per-shard pallas launches under a mesh.
    For cross-attention (kv_override=enc_out) the cache holds nothing; we
    recompute k/v from enc_out (cheap relative to self-attn cache traffic;
    a production enc-dec would cache these too — see serving engine, which
    does exactly that at the engine level).
    """
    B, _, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = cfg.attn_scale or 1.0 / (hd ** 0.5)

    q = (x @ params["wq"]).reshape(B, 1, H, hd)

    if kv_override is not None:
        Skv = kv_override.shape[1]
        k = (kv_override @ params["wk"]).reshape(B, Skv, K, hd)
        v = (kv_override @ params["wv"]).reshape(B, Skv, K, hd)
        kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
        mask = make_mask_fn("full")(pos[:, None], kv_pos)
        out = _attend(q, k, v, mask, scale, cfg.attn_logit_softcap)
        return (out.reshape(B, 1, H * hd) @ params["wo"]), cache_layer

    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = (x @ params["wk"]).reshape(B, 1, K, hd)
    v_new = (x @ params["wv"]).reshape(B, 1, K, hd)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    W = cache_layer["k"].shape[1]
    slot = (pos % W).astype(jnp.int32)  # (B,)

    def write(buf, new, slot_b):
        return jax.lax.dynamic_update_slice(buf, new, (slot_b, 0, 0))

    k_buf = jax.vmap(write)(cache_layer["k"], k_new[:, 0:1], slot)
    v_buf = jax.vmap(write)(cache_layer["v"], v_new[:, 0:1], slot)
    k_buf, v_buf, q, kv_sh = _pin_kv_sharding(cfg, pc, k_buf, v_buf, q)
    kv_pos = cache_layer["kv_pos"]
    kv_pos = jax.vmap(lambda p, s, val: jax.lax.dynamic_update_slice(p, val, (s,)))(
        kv_pos, slot, pos[:, None].astype(jnp.int32))

    kind = "local" if spec_mixer == "attn_local" else "causal"
    if cfg.attn_impl == "pallas":
        # split-KV flash decode: ring-buffer kv_pos masking, sliding window,
        # and softcap fused in-kernel; tiles beyond each slot's filled
        # prefix are skipped via the scalar-prefetched pos
        from repro.kernels.partition import sharded_flash_decode

        window = cfg.sliding_window if kind == "local" else 0
        out = sharded_flash_decode(cfg, pc, q[:, 0], k_buf, v_buf, kv_pos,
                                   pos.astype(jnp.int32), scale=scale,
                                   window=window,
                                   logit_cap=cfg.attn_logit_softcap)[:, None]
    else:
        mask = make_mask_fn(kind, cfg.sliding_window)(pos[:, None], kv_pos)
        out = _attend(q, k_buf, v_buf, mask, scale, cfg.attn_logit_softcap)
        if kv_sh is not None:
            out = jax.lax.with_sharding_constraint(out, kv_sh)
    out = out.reshape(B, 1, H * hd) @ params["wo"]
    return out, {"k": k_buf, "v": v_buf, "kv_pos": kv_pos}


def paged_attention_step(params, cfg, spec_mixer: str, x, paged, cache_layer,
                         *, pc=None):
    """Cached attention over the PAGED KV layout, for 1..C query tokens per
    slot (C == 1 is a decode step; C > 1 is a chunked-prefill extend).

    x: (B, C, d). ``paged`` carries the step's precomputed coordinates (see
    ``model.extend``): positions (B, C) absolute query positions, pos (B,),
    valid (B,) real-token counts (rows >= valid are padding/dead slots whose
    writes are redirected to the null page), flat (B, C) flattened pool-row
    write indices, kv_pos (N, page) ALREADY updated for this step's rows,
    page_table (B, P). cache_layer: {"k","v"} physical pools (N, page, K,
    hd). Returns (out (B, C, d), new pools).

    Reads: ``attn_impl == "pallas"`` routes single-token steps through the
    page-table-aware flash-decode kernel (O(resident pages) traffic); the
    jnp path and multi-token extends gather the slot's logical view through
    the page table — unallocated entries hit the null page, whose kv_pos is
    -1, so the standard mask neutralises them.
    """
    from repro.models.kvcache import gather_paged_kv

    B, C, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = cfg.attn_scale or 1.0 / (hd ** 0.5)
    positions = paged["positions"]
    flat = paged["flat"].reshape(-1)

    q = (x @ params["wq"]).reshape(B, C, H, hd)
    k_new = (x @ params["wk"]).reshape(B, C, K, hd)
    v_new = (x @ params["wv"]).reshape(B, C, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    k_pool, v_pool = cache_layer["k"], cache_layer["v"]
    N, page, _, _ = k_pool.shape
    k_pool = k_pool.reshape(N * page, K, hd).at[flat].set(
        k_new.reshape(B * C, K, hd)).reshape(N, page, K, hd)
    v_pool = v_pool.reshape(N * page, K, hd).at[flat].set(
        v_new.reshape(B * C, K, hd)).reshape(N, page, K, hd)
    new_cache = {"k": k_pool, "v": v_pool}

    kind = "local" if spec_mixer == "attn_local" else "causal"
    window = cfg.sliding_window if kind == "local" else 0
    if cfg.attn_impl == "pallas" and C == 1:
        from repro.kernels.partition import sharded_flash_decode_paged

        out = sharded_flash_decode_paged(
            cfg, pc, q[:, 0], k_pool, v_pool, paged["kv_pos"],
            paged["page_table"], paged["pos"].astype(jnp.int32),
            scale=scale, window=window,
            logit_cap=cfg.attn_logit_softcap)[:, None]
    else:
        k = gather_paged_kv(k_pool, paged["page_table"])   # (B, L, K, hd)
        v = gather_paged_kv(v_pool, paged["page_table"])
        kvp = gather_paged_kv(paged["kv_pos"], paged["page_table"])
        mask = make_mask_fn(kind, cfg.sliding_window)(positions, kvp)
        out = _attend(q, k, v, mask, scale, cfg.attn_logit_softcap)
    return out.reshape(B, C, H * hd) @ params["wo"], new_cache


def fill_cache_from_prefill(cfg, spec_mixer: str, k, v, positions, max_len: int):
    """Build a decode cache layer from prefill k/v (B, S, K, hd)."""
    B, S, K, hd = k.shape
    W = cache_window(cfg, spec_mixer, max_len)
    take = min(S, W)
    k_tail, v_tail = k[:, S - take:], v[:, S - take:]
    pos_tail = positions[:, S - take:]
    k_buf = jnp.zeros((B, W, K, hd), k.dtype)
    v_buf = jnp.zeros((B, W, K, hd), v.dtype)
    kv_pos = jnp.full((B, W), -1, jnp.int32)
    slots = pos_tail % W  # (B, take)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], slots.shape)
    k_buf = k_buf.at[bidx, slots].set(k_tail)
    v_buf = v_buf.at[bidx, slots].set(v_tail)
    kv_pos = kv_pos.at[bidx, slots].set(pos_tail)
    return {"k": k_buf, "v": v_buf, "kv_pos": kv_pos}


def cache_window(cfg, spec_mixer: str, max_len: int) -> int:
    if spec_mixer == "attn_local" and cfg.sliding_window:
        return min(cfg.sliding_window, max_len)
    return max_len
