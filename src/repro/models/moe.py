"""Sparse Mixture-of-Experts layer.

Three compute paths, selectable per call:

``dense``   — every expert on every token (exact, simple). Used by tiny smoke
              tests and by HC-SMoE *calibration*, which needs E_j(x) for ALL
              experts per Eq. (4) of the paper.
``ragged``  — dropless sort-gather path: top-k -> stable sort by expert id ->
              gather -> ``jax.lax.ragged_dot`` grouped GEMM -> weighted
              scatter-add. Differentiable end-to-end; the production default
              under pjit. This is the TPU-native adaptation of the paper's
              HF per-expert loop (DESIGN.md §3).
``pallas``  — same dispatch as ``ragged`` but the grouped GEMMs run through
              the Pallas kernel in ``repro.kernels`` (TPU target; CPU tests
              run it in interpret mode).

Expert *merging* is represented by a ``group_map: (E,) int32`` in the layer
state mapping original expert ids to merged expert slots (< num_merged). The
router is untouched (paper Fig. 3): routing runs over the original E logits
and the chosen ids are remapped through ``group_map`` before dispatch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.ffn import ffn_forward, init_ffn
from repro.models.layers import activation, dense_init


class MoEStats(NamedTuple):
    """Calibration statistics accumulated per MoE layer (paper Alg. 1)."""

    out_sum: jax.Array       # (E, d)   sum over tokens of E_j(x)
    token_count: jax.Array   # ()       number of tokens seen
    freq: jax.Array          # (E,)     top-k selection counts
    logits_sample: jax.Array  # (T_sub, E) router logits on first T_sub tokens
    act_sample: jax.Array    # (E, T_sub_act, f) intermediate activations
    x_sample: jax.Array      # (T_sub, d) layer inputs (for O-prune & quality)


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d, dtype = cfg.d_model, jnp.dtype(cfg.dtype)
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    params = {
        "router": dense_init(k_r, (d, m.num_experts), dtype=jnp.float32),
        # additive logit mask; pruning baselines set -1e9 on removed experts
        "router_mask": jnp.zeros((m.num_experts,), jnp.float32),
        "wg": dense_init(k_g, (m.num_experts, d, m.expert_ffn_dim), dtype, in_axis=1),
        "wu": dense_init(k_u, (m.num_experts, d, m.expert_ffn_dim), dtype, in_axis=1),
        "wd": dense_init(k_d, (m.num_experts, m.expert_ffn_dim, d), dtype, in_axis=1),
    }
    if m.num_shared_experts:
        params["shared"] = init_ffn(
            k_s, d, m.num_shared_experts * m.shared_expert_ffn_dim, dtype)
    return params


def identity_group_map(num_experts: int) -> jax.Array:
    return jnp.arange(num_experts, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def router_probs(logits, cfg):
    """Returns (topk_probs (T,k), topk_idx (T,k)). logits: (T, E) fp32."""
    m = cfg.moe
    if m.router_mode == "softmax_topk":
        top_logits, top_idx = jax.lax.top_k(logits, m.top_k)
        probs = jax.nn.softmax(top_logits, axis=-1)
    elif m.router_mode == "softmax_all":
        full = jax.nn.softmax(logits, axis=-1)
        probs, top_idx = jax.lax.top_k(full, m.top_k)
        probs = probs * m.routed_scaling_factor
    else:
        raise ValueError(m.router_mode)
    return probs, top_idx


def load_balancing_loss(logits, top_idx, num_experts: int):
    """Switch-Transformer aux loss + router z-loss."""
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    density = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(top_idx, num_experts, dtype=jnp.float32)
    usage = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
    lb = num_experts * jnp.sum(density * usage)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return lb, z


# ---------------------------------------------------------------------------
# Expert compute paths
# ---------------------------------------------------------------------------


def _dense_expert_outputs(params, x, act: str):
    """All-experts output: x (T, d) -> (T, E, d)."""
    f = activation(act)
    h = f(jnp.einsum("td,edf->tef", x, params["wg"])) * jnp.einsum(
        "td,edf->tef", x, params["wu"])
    return jnp.einsum("tef,efd->ted", h, params["wd"])


def _ragged_expert_ffn(x_sorted, params, group_sizes, act: str, use_pallas: bool):
    """Grouped GEMM over contiguous expert segments. x_sorted: (N, d)."""
    f = activation(act)
    if use_pallas:
        from repro.kernels.ops import grouped_ffn
        return grouped_ffn(x_sorted, params["wg"], params["wu"], params["wd"],
                           group_sizes, act)
    h = f(jax.lax.ragged_dot(x_sorted, params["wg"], group_sizes)) * \
        jax.lax.ragged_dot(x_sorted, params["wu"], group_sizes)
    return jax.lax.ragged_dot(h, params["wd"], group_sizes)


def _ep_ragged_forward(params, xt, probs, dispatch_idx, n_slots: int, *,
                       mesh, ep_axis: str, dp_axes, act: str,
                       use_pallas: bool):
    """Expert-parallel ragged forward: shard-local grouped GEMMs + psum.

    ``xt (T, d)``, ``probs``/``dispatch_idx (T, k)`` enter through a
    ``shard_map`` whose in_specs never mention ``ep_axis`` — the explicit
    replication point that guarantees every expert shard sees IDENTICAL
    routing decisions (computed once, in GSPMD land, from replicated router
    logits). The seed instead let GSPMD partition the dispatch and the XLA
    partitioner sharded ``group_sizes`` over 'model', misreading local
    slices as global cumulative offsets (err ~5.0, the old
    ``test_ep_sharding_lowers`` xfail).

    Each shard owns the contiguous expert slice ``[s*E/tp, (s+1)*E/tp)``:
    it remaps the replicated dispatch ids to local group ids (non-owned
    tokens go to a zero-weight sentinel group and combine with weight 0),
    runs the grouped GEMMs on its local experts only — no weight
    all-gather — and one ``psum`` over ``ep_axis`` combines the partial
    token outputs. The token dim shards over ``dp_axes`` when divisible so
    data parallelism is preserved end-to-end.
    """
    from jax.sharding import PartitionSpec as P

    from repro.parallel.compat import shard_map_compat

    T, d = xt.shape
    k = dispatch_idx.shape[-1]
    ep_size = int(mesh.shape[ep_axis])
    if n_slots % ep_size != 0:
        raise ValueError(
            f"expert parallelism needs the expert slot count ({n_slots}) "
            f"divisible by the '{ep_axis}' mesh axis ({ep_size}); pad the "
            f"stacks with repro.parallel.pad_expert_slots first")
    e_loc = n_slots // ep_size
    dp_axes = tuple(a for a in dp_axes if a in mesh.shape and a != ep_axis)
    dp_size = 1
    for a in dp_axes:
        dp_size *= int(mesh.shape[a])
    tok = dp_axes if (dp_axes and T % dp_size == 0) else None

    def local(xt, didx, dprobs, wg, wu, wd):
        shard = jax.lax.axis_index(ep_axis)
        flat_idx = didx.reshape(-1)
        local_idx = flat_idx - shard * e_loc
        owned = (local_idx >= 0) & (local_idx < e_loc)
        local_idx = jnp.where(owned, local_idx, e_loc)  # sentinel group
        order = jnp.argsort(local_idx, stable=True)
        inv_token = order // k
        xs = jnp.take(xt, inv_token, axis=0)
        group_sizes = jnp.bincount(local_idx, length=e_loc + 1).astype(
            jnp.int32)
        pad = lambda w: jnp.concatenate([w, jnp.zeros_like(w[:1])])  # noqa: E731
        ys = _ragged_expert_ffn(
            xs, {"wg": pad(wg), "wu": pad(wu), "wd": pad(wd)}, group_sizes,
            act, use_pallas)
        w = jnp.take(jnp.where(owned, dprobs.reshape(-1), 0.0), order)
        ys = ys * w[:, None].astype(ys.dtype)
        out = jnp.zeros((xt.shape[0], d), ys.dtype).at[inv_token].add(ys)
        return jax.lax.psum(out, ep_axis)

    e_spec = P(ep_axis, None, None)
    fn = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(tok, None), P(tok, None), P(tok, None),
                  e_spec, e_spec, e_spec),
        out_specs=P(tok, None))
    return fn(xt, dispatch_idx, probs, params["wg"], params["wu"],
              params["wd"])


def _capacity_dispatch(x, probs, dispatch_idx, n_slots: int,
                       capacity_factor: float):
    """GShard/Switch capacity dispatch, ROW-WISE and GATHER-ONLY.

    Each batch row builds its own (E, C, d) expert batch so the batch dim
    stays dp-sharded end-to-end, and the dispatch/combine are expressed
    purely with batched gathers + an inverse permutation (no scatters: GSPMD
    partitions batched gathers cleanly but replicates batched scatters —
    the scatter variant cost 2 TB/device of all-gathers on the mixtral
    dry-run).

    x: (B, S, d); probs/dispatch_idx: (B, S, k). Tokens beyond an expert's
    per-row capacity C = ceil(S*k/E * capacity_factor) are dropped
    (weight-0 combine) — the standard TPU MoE trade-off. No (E, N, d) mask
    tensor is ever built (the XLA ragged path materialised 19 TB of masks
    at DeepSeek scale).
    """
    B, S, k = dispatch_idx.shape
    m = S * k
    d = x.shape[-1]
    flat_idx = dispatch_idx.reshape(B, m)
    flat_probs = probs.reshape(B, m)
    cap = int(max(1, -(-m // n_slots) * capacity_factor))

    order = jnp.argsort(flat_idx, axis=1, stable=True)  # (B, m)
    sorted_idx = jnp.take_along_axis(flat_idx, order, axis=1)
    # per-row segment boundaries
    eids = jnp.arange(n_slots, dtype=sorted_idx.dtype)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, eids, side="left"))(
        sorted_idx)  # (B, E)
    ends = jax.vmap(lambda row: jnp.searchsorted(row, eids, side="right"))(
        sorted_idx)

    # slot (e, c) <- sorted position starts[e] + c (valid while < ends[e])
    slot_pos = starts[:, :, None] + jnp.arange(cap, dtype=jnp.int32)[None, None]
    slot_valid = slot_pos < ends[:, :, None]  # (B, E, C)
    slot_pos = jnp.minimum(slot_pos, m - 1).reshape(B, n_slots * cap)
    slot_src = jnp.take_along_axis(order, slot_pos, axis=1) // k  # token pos
    x_exp = jnp.take_along_axis(x, slot_src[..., None], axis=1)
    x_exp = jnp.where(slot_valid.reshape(B, n_slots * cap)[..., None], x_exp,
                      0).reshape(B, n_slots, cap, d)

    # combine-side indices: sorted position -> its slot (or sentinel)
    pos_in_expert = (jnp.arange(m, dtype=jnp.int32)[None]
                     - jnp.take_along_axis(starts, sorted_idx, axis=1))
    keep = pos_in_expert < cap
    dest = jnp.where(keep, sorted_idx * cap + pos_in_expert, n_slots * cap)
    inv_order = jnp.argsort(order, axis=1)  # unsort permutation
    probs_sorted = jnp.take_along_axis(flat_probs, order, axis=1)
    return x_exp, (dest, inv_order, probs_sorted, keep, cap, k)


def _capacity_combine(y_exp, combine_info, S: int, d: int):
    dest, inv_order, probs_sorted, keep, cap, k = combine_info
    B, n_slots = y_exp.shape[0], y_exp.shape[1]
    y_flat = jnp.concatenate(
        [y_exp.reshape(B, n_slots * cap, d),
         jnp.zeros((B, 1, d), y_exp.dtype)], axis=1)
    ys = jnp.take_along_axis(
        y_flat, jnp.minimum(dest, n_slots * cap)[..., None], axis=1)
    w = jnp.where(keep, probs_sorted, 0.0)[..., None].astype(ys.dtype)
    ys = ys * w  # (B, m, d) in sorted order
    # unsort back to (token, k) order, then reduce over k — gather-only
    ys = jnp.take_along_axis(ys, inv_order[..., None], axis=1)
    return ys.reshape(B, S, k, d).sum(axis=2)


def _capacity_expert_ffn(x_exp, params, act: str):
    """Batched per-expert FFN: (B,E,C,d) x (E,d,f) einsums — MXU-native."""
    f = activation(act)
    h = f(jnp.einsum("becd,edf->becf", x_exp, params["wg"])) * jnp.einsum(
        "becd,edf->becf", x_exp, params["wu"])
    return jnp.einsum("becf,efd->becd", h, params["wd"])


def moe_forward(params, cfg, x, *, group_map: Optional[jax.Array] = None,
                num_groups: Optional[int] = None, mode: str = "ragged",
                capture_stats: bool = False, t_sub: int = 256,
                act_sub: int = 64, capacity_factor: float = 1.25,
                act_shard=None, ep_axis: Optional[str] = None,
                dp_axes=()):
    """x: (B, S, d) -> (out (B, S, d), aux dict).

    group_map/num_groups implement merged-expert serving: after HC-SMoE the
    stacked expert weights have ``num_groups`` live entries (padded back to E
    slots or resized) and routing ids are remapped through ``group_map``.

    ``ep_axis`` (with a mesh in context) switches the ragged/pallas paths to
    the expert-parallel ``shard_map`` forward (:func:`_ep_ragged_forward`):
    routing stays replicated, expert GEMMs run shard-local on the E/tp
    slice each device owns.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ params["router"]  # (T, E)
    if "router_mask" in params:
        logits = logits + params["router_mask"]
    probs, top_idx = router_probs(logits, cfg)
    lb_loss, z_loss = load_balancing_loss(logits, top_idx, m.num_experts)

    if group_map is not None:
        dispatch_idx = jnp.take(group_map, top_idx)  # remap to merged slots
        n_slots = num_groups if num_groups is not None else params["wg"].shape[0]
    else:
        dispatch_idx = top_idx
        n_slots = params["wg"].shape[0]

    if mode == "dense":
        all_out = _dense_expert_outputs(params, xt, cfg.act)  # (T, E', d)
        one_hot = jax.nn.one_hot(dispatch_idx, n_slots, dtype=probs.dtype)
        combine = jnp.einsum("tk,tke->te", probs, one_hot)  # (T, E')
        out = jnp.einsum("te,ted->td", combine.astype(all_out.dtype), all_out)
    elif mode == "capacity":
        x_exp, info = _capacity_dispatch(
            x, probs.reshape(B, S, m.top_k),
            dispatch_idx.reshape(B, S, m.top_k), n_slots,
            capacity_factor=capacity_factor)
        if act_shard is not None:
            # batch (row) dim stays dp-sharded through the expert batches;
            # without the constraint GSPMD replicated the expert compute on
            # every data shard (16x model FLOPs per chip, measured). With
            # EP the expert dim also shards over tp.
            from jax.sharding import PartitionSpec as _P

            b_ax, e_ax = (act_shard if isinstance(act_shard, tuple)
                          else (act_shard, None))
            x_exp = jax.lax.with_sharding_constraint(
                x_exp, _P(b_ax, e_ax, None, None))
        y_exp = _capacity_expert_ffn(x_exp, params, cfg.act)
        if act_shard is not None:
            y_exp = jax.lax.with_sharding_constraint(
                y_exp, _P(b_ax, e_ax, None, None))
        out = _capacity_combine(y_exp, info, S, d).reshape(T, d)
    elif mode in ("ragged", "pallas"):
        k = m.top_k
        ep_mesh = None
        if ep_axis is not None:
            from repro.parallel.sharding import get_context_mesh

            ep_mesh = get_context_mesh()
            if ep_mesh is None:
                # refuse to fall through: the plain GSPMD path on
                # EP-sharded weights is exactly the silent err~5.0
                # divergence this module exists to prevent
                raise ValueError(
                    "ep_axis was requested (ParallelConfig.ep=True) but no "
                    "mesh is in context; run the jitted call under "
                    "`with mesh:` so the shard_map EP forward can bind it")
        # an ep_axis absent from the mesh or of size 1 cannot actually
        # shard the expert dim, so the plain path is exact there
        if (ep_mesh is not None and ep_axis in ep_mesh.shape
                and int(ep_mesh.shape[ep_axis]) > 1):
            out = _ep_ragged_forward(
                params, xt, probs, dispatch_idx, n_slots, mesh=ep_mesh,
                ep_axis=ep_axis, dp_axes=dp_axes, act=cfg.act,
                use_pallas=(mode == "pallas"))
        else:
            flat_idx = dispatch_idx.reshape(T * k)
            flat_probs = probs.reshape(T * k)
            order = jnp.argsort(flat_idx, stable=True)
            inv_token = order // k  # source token of each sorted slot
            xs = jnp.take(xt, inv_token, axis=0)  # (T*k, d)
            group_sizes = jnp.bincount(flat_idx,
                                       length=n_slots).astype(jnp.int32)
            ys = _ragged_expert_ffn(xs, params, group_sizes, cfg.act,
                                    use_pallas=(mode == "pallas"))
            ys = ys * jnp.take(flat_probs, order)[:, None].astype(ys.dtype)
            out = jnp.zeros((T, d), ys.dtype).at[inv_token].add(ys)
    else:
        raise ValueError(mode)

    if m.num_shared_experts:
        out = out + ffn_forward(params["shared"], xt, cfg.act)

    aux = {"lb_loss": lb_loss, "z_loss": z_loss}
    if capture_stats:
        # Stats are defined over the ORIGINAL expert set only (paper Alg. 1
        # calibrates the un-merged model): freq/logits use m.num_experts, so
        # computing out_sum/act_sample over merged slot weights would emit a
        # shape-inconsistent MoEStats. Refuse merged params outright — the
        # slot count is static, so this raises at trace time.
        if params["wg"].shape[0] != m.num_experts:
            raise ValueError(
                f"capture_stats=True requires pre-merge expert weights: "
                f"params hold {params['wg'].shape[0]} expert slots but "
                f"cfg.moe.num_experts={m.num_experts}. Run calibration on "
                f"the original params (before apply_hcsmoe).")
        all_out = (_dense_expert_outputs(params, xt, cfg.act)
                   if mode != "dense" else all_out)  # (T, E, d) original slots
        f = activation(cfg.act)
        h_act = f(jnp.einsum("td,edf->tef", xt[:act_sub], params["wg"])) * \
            jnp.einsum("td,edf->tef", xt[:act_sub], params["wu"])  # (t, E, f)
        one_hot_freq = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
        aux["stats"] = MoEStats(
            out_sum=jnp.sum(all_out.astype(jnp.float32), axis=0),
            token_count=jnp.asarray(T, jnp.float32),
            freq=jnp.sum(one_hot_freq, axis=(0, 1)),
            logits_sample=logits[:t_sub],
            act_sample=jnp.transpose(h_act, (1, 0, 2)).astype(jnp.float32),
            x_sample=xt[:t_sub].astype(jnp.float32),
        )

    return out.reshape(B, S, d), aux
