"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, exponential gating, per-head recurrent connections).

arXiv:2405.04517. TPU adaptation: the official CUDA kernels stream the
recurrence through registers; here the mLSTM uses the stabilized *chunkwise*
parallel form (flash-linear-attention style) — a ``lax.scan`` over chunks
carrying (C, n, m) with dense intra-chunk einsums that map onto the MXU —
and the sLSTM (a true nonlinear recurrence, not chunkable) uses a per-step
``lax.scan``, which is the honest TPU cost of that block type.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

NEG = -1.0e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg) -> dict:
    xc = cfg.xlstm
    d = cfg.d_model
    d_in = int(xc.mlstm_proj_factor * d)
    ks = jax.random.split(key, 7)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "up": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (xc.conv1d_kernel, d_in), dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(ks[2], (d_in, d_in), dtype),
        "wk": dense_init(ks[3], (d_in, d_in), dtype),
        "wv": dense_init(ks[4], (d_in, d_in), dtype),
        "w_gates": dense_init(ks[5], (d_in, 2 * xc.num_heads), dtype=jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((xc.num_heads,)),
                                    jnp.full((xc.num_heads,), 3.0)]),  # f-bias>0
        "out_norm": jnp.zeros((d_in,), jnp.float32),
        "down": dense_init(ks[6], (d_in, d), dtype),
    }


def _mlstm_chunk(carry, args, scale):
    """One chunk of the stabilized chunkwise mLSTM.

    carry: C (B,H,dk,dk) f32, n (B,H,dk) f32, m (B,H) f32
    args:  q,k,v (B,c,H,dk), logi/logf (B,c,H) f32
    """
    C, n, m = carry
    q, k, v, logi, logf = args
    B, c, H, dk = q.shape
    qf = q.astype(jnp.float32) * scale
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    b = jnp.cumsum(logf, axis=1)  # (B,c,H) inclusive log-decay
    a = b + m[:, None, :]  # carry path log-scale per position
    # intra-chunk log weights l[t, j] = b_t - b_j + logi_j  (j <= t)
    l = b[:, :, None, :] - b[:, None, :, :] + logi[:, None, :, :]  # (B,t,j,H)
    causal = jnp.tril(jnp.ones((c, c), bool))
    l = jnp.where(causal[None, :, :, None], l, NEG)
    m_t = jnp.maximum(a, jnp.max(l, axis=2))  # (B,c,H)
    w = jnp.exp(l - m_t[:, :, None, :])  # (B,t,j,H)
    carry_scale = jnp.exp(a - m_t)  # (B,c,H)

    scores = jnp.einsum("bthd,bjhd->btjh", qf, kf) * w
    num = (jnp.einsum("btjh,bjhd->bthd", scores, vf)
           + carry_scale[..., None] * jnp.einsum("bthd,bhde->bthe", qf, C))
    n_t = (jnp.einsum("btjh,bjhd->bthd", w, kf)
           + carry_scale[..., None] * n[:, None])
    denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qf, n_t)),
                        jnp.exp(-m_t))
    h = num / denom[..., None]  # (B,c,H,dk)

    # end-of-chunk state
    b_end = b[:, -1:, :]  # (B,1,H)
    m_new = jnp.maximum(b_end[:, 0] + m, jnp.max(b_end - b + logi, axis=1))
    w_end = jnp.exp(b_end - b + logi - m_new[:, None])  # (B,c,H)
    decay_end = jnp.exp(b_end[:, 0] + m - m_new)  # (B,H)
    C_new = (decay_end[..., None, None] * C
             + jnp.einsum("bch,bchd,bche->bhde", w_end, kf, vf))
    n_new = decay_end[..., None] * n + jnp.einsum("bch,bchd->bhd", w_end, kf)
    return (C_new, n_new, m_new), h


def mlstm_forward(params, cfg, x, *, return_state: bool = False):
    xc = cfg.xlstm
    B, S, d = x.shape
    d_in = int(xc.mlstm_proj_factor * d)
    H = xc.num_heads
    dk = d_in // H

    uz = x @ params["up"]
    u, z = uz[..., :d_in], uz[..., d_in:]

    ker = xc.conv1d_kernel
    u_pad = jnp.pad(u, ((0, 0), (ker - 1, 0), (0, 0)))
    windows = jnp.stack([u_pad[:, i:i + S] for i in range(ker)], axis=-1)
    u_conv = jax.nn.silu(
        jnp.einsum("bsdk,kd->bsd", windows, params["conv_w"]) + params["conv_b"])

    q = (u_conv @ params["wq"]).reshape(B, S, H, dk)
    k = (u_conv @ params["wk"]).reshape(B, S, H, dk)
    v = (u @ params["wv"]).reshape(B, S, H, dk)
    gates = u.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    logi = gates[..., :H]  # exponential input gate: log i = raw
    logf = jax.nn.log_sigmoid(gates[..., H:])

    from repro.models.flags import chunking

    c, unroll_inner = chunking(S, min(xc.chunk_size, S))
    c = min(c, S)
    n_chunks = S // c
    assert S % c == 0, "seq must be divisible by mLSTM chunk"

    def resh(t):
        return t.reshape(B, n_chunks, c, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    carry0 = (jnp.zeros((B, H, dk, dk), jnp.float32),
              jnp.zeros((B, H, dk), jnp.float32),
              jnp.zeros((B, H), jnp.float32))
    scale = 1.0 / (dk ** 0.5)
    body = jax.checkpoint(lambda cy, a: _mlstm_chunk(cy, a, scale),
                          prevent_cse=unroll_inner)
    carry, hs = jax.lax.scan(body, carry0,
                             (resh(q), resh(k), resh(v), resh(logi), resh(logf)),
                             unroll=n_chunks if unroll_inner else 1)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, d_in)

    h = rms_norm(h, params["out_norm"], cfg.norm_eps).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ params["down"]
    if return_state:
        ker_state = u_pad[:, S:S + ker - 1] if ker > 1 else jnp.zeros((B, 0, d_in), x.dtype)
        return out, {"C": carry[0], "n": carry[1], "m": carry[2], "conv": ker_state}
    return out, None


def mlstm_decode(params, cfg, x, cache_layer):
    """x: (B,1,d). cache: C,n,m + conv tail."""
    xc = cfg.xlstm
    B = x.shape[0]
    d = cfg.d_model
    d_in = int(xc.mlstm_proj_factor * d)
    H = xc.num_heads
    dk = d_in // H

    uz = x[:, 0] @ params["up"]
    u, z = uz[:, :d_in], uz[:, d_in:]
    conv_buf = jnp.concatenate([cache_layer["conv"], u[:, None]], axis=1)
    u_conv = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", conv_buf, params["conv_w"]) + params["conv_b"])

    q = (u_conv @ params["wq"]).reshape(B, H, dk).astype(jnp.float32) / (dk ** 0.5)
    k = (u_conv @ params["wk"]).reshape(B, H, dk).astype(jnp.float32)
    v = (u @ params["wv"]).reshape(B, H, dk).astype(jnp.float32)
    gates = u.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    logi, logf = gates[:, :H], jax.nn.log_sigmoid(gates[:, H:])

    C, n, m = cache_layer["C"], cache_layer["n"], cache_layer["m"]
    m_new = jnp.maximum(logf + m, logi)
    f_s = jnp.exp(logf + m - m_new)[..., None]
    i_s = jnp.exp(logi - m_new)[..., None]
    # (B,H,dk,dk): k outer v
    C = f_s[..., None] * C + i_s[..., None] * (k[..., :, None] * v[..., None, :])
    n = f_s * n + i_s * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / denom[..., None]).reshape(B, d_in)
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    out = ((h.astype(x.dtype) * jax.nn.silu(z)) @ params["down"])[:, None]
    return out, {"C": C, "n": n, "m": m_new, "conv": conv_buf[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg) -> dict:
    xc = cfg.xlstm
    d = cfg.d_model
    H = xc.num_heads
    dh = d // H
    d_up = int(xc.slstm_proj_factor * d)
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "w": dense_init(ks[0], (d, 4 * d), dtype=jnp.float32),
        "r": dense_init(ks[1], (H, dh, 4 * dh), dtype=jnp.float32),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]),  # i, f(+bias), z, o
        "out_norm": jnp.zeros((d,), jnp.float32),
        "up": dense_init(ks[2], (d, 2 * d_up), dtype),
        "down": dense_init(ks[3], (d_up, d), dtype),
    }


def _slstm_step(params, cfg, xw, state):
    """xw: (B, 4d) pre-computed input projection for this step."""
    H = cfg.xlstm.num_heads
    d = cfg.d_model
    dh = d // H
    c, n, h, m = state
    B = xw.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h.reshape(B, H, dh), params["r"]).reshape(B, 4 * d)
    raw = xw + rec + params["b"]
    it, ft, zt, ot = jnp.split(raw, 4, axis=-1)
    logi, logf = it, jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, logi)
    i_s, f_s = jnp.exp(logi - m_new), jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(zt)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(params, cfg, x, *, return_state: bool = False):
    B, S, d = x.shape
    xw = (x.astype(jnp.float32) @ params["w"])  # (B,S,4d)
    state0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3)) + (
        jnp.full((B, d), NEG, jnp.float32),)

    def body(state, xw_t):
        new = _slstm_step(params, cfg, xw_t, state)
        return new, new[2]

    state, hs = jax.lax.scan(body, state0, xw.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)  # (B,S,d)
    h = rms_norm(h, params["out_norm"], cfg.norm_eps).astype(x.dtype)
    d_up = params["down"].shape[0]
    uz = h @ params["up"]
    out = (jax.nn.gelu(uz[..., :d_up]) * uz[..., d_up:]) @ params["down"]
    if return_state:
        return out, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
    return out, None


def slstm_decode(params, cfg, x, cache_layer):
    B = x.shape[0]
    xw = x[:, 0].astype(jnp.float32) @ params["w"]
    state = (cache_layer["c"], cache_layer["n"], cache_layer["h"], cache_layer["m"])
    state = _slstm_step(params, cfg, xw, state)
    h = rms_norm(state[2], params["out_norm"], cfg.norm_eps).astype(x.dtype)
    d_up = params["down"].shape[0]
    uz = h @ params["up"]
    out = ((jax.nn.gelu(uz[:, :d_up]) * uz[:, d_up:]) @ params["down"])[:, None]
    return out, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
