from repro.models.model import Model, build_model, lm_cross_entropy  # noqa: F401
