"""Decoder stack assembly: init + forward (train/prefill/decode) via
``lax.scan`` over stacked per-block params, so HLO size is independent of
depth. Handles every assigned mixer/FFN combination, VLM embedding prepend,
optional cross-attention (enc-dec decoder), MoE calibration capture, and
merged-expert group maps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.models import xlstm as xl
from repro.models.ffn import ffn_forward, init_ffn
from repro.models.layers import init_rms_norm, rms_norm
from repro.models.moe import identity_group_map, init_moe, moe_forward

ATTN_KINDS = ("attn", "attn_local", "attn_global")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(key, cfg, spec, *, with_cross: bool = False) -> dict:
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    p = {"ln1": init_rms_norm(d)}
    if spec.mixer in ATTN_KINDS:
        p["mixer"] = attn.init_attention(ks[0], cfg)
    elif spec.mixer == "mla":
        p["mixer"] = mla_mod.init_mla(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = mam.init_mamba(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mixer"] = xl.init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["mixer"] = xl.init_slstm(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if with_cross:
        p["ln_cross"] = init_rms_norm(d)
        p["cross"] = attn.init_attention(ks[1], cfg)
    if spec.ffn == "dense":
        p["ln2"] = init_rms_norm(d)
        p["ffn"] = init_ffn(ks[2], d, cfg.d_ff, cfg.dtype)
    elif spec.ffn == "moe":
        p["ln2"] = init_rms_norm(d)
        p["moe"] = init_moe(ks[2], cfg)
        p["moe"]["group_map"] = identity_group_map(cfg.moe.num_experts)
    return p


def init_stack(key, cfg, *, with_cross: bool = False) -> dict:
    """Prefix layers (unstacked) + scanned blocks (stacked over n_blocks)."""
    k_prefix, k_blocks = jax.random.split(key)
    if cfg.first_dense_layers:
        prefix = tuple(
            init_layer(k, cfg,
                       type(cfg.pattern[0])(mixer=cfg.pattern[0].mixer, ffn="dense"),
                       with_cross=with_cross)
            for k in jax.random.split(k_prefix, cfg.first_dense_layers)
        )
    else:
        prefix = ()

    def one_block(k):
        keys = jax.random.split(k, len(cfg.pattern))
        return {
            f"layer{i}": init_layer(keys[i], cfg, spec, with_cross=with_cross)
            for i, spec in enumerate(cfg.pattern)
        }

    blocks = jax.vmap(one_block)(jax.random.split(k_blocks, cfg.num_blocks))
    return {"prefix": prefix, "blocks": blocks}


# ---------------------------------------------------------------------------
# Single layer application
# ---------------------------------------------------------------------------


def apply_layer(lp, cfg, spec, x, positions, *, mode: str,
                cache_layer=None, cache_max_len: int = 0,
                moe_mode: str = "ragged", capture_stats: bool = False,
                enc_out: Optional[jax.Array] = None,
                mask_kind: str = "causal", pc=None, paged=None):
    """Returns (x, new_cache_layer, aux).

    ``paged`` (decode/extend modes only) carries the paged-KV step
    coordinates built by ``model.extend``; attention mixers then read/write
    the shared page pools instead of per-slot ring buffers. ``mode ==
    "extend"`` is the multi-token cached step (chunked prefill) and is only
    defined for paged attention layers.
    """
    if pc is not None:
        from repro.parallel.sharding import gather_layer_params

        lp = gather_layer_params(lp, pc)
    aux = {}
    new_cache = dict(cache_layer) if isinstance(cache_layer, dict) else cache_layer
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)

    mixer = spec.mixer
    if mode in ("decode", "extend") and paged is not None:
        if mixer not in ATTN_KINDS:
            raise ValueError(
                f"paged KV cache supports attention mixers only, got {mixer}")
        out, new_cache = attn.paged_attention_step(lp["mixer"], cfg, mixer,
                                                   h, paged, cache_layer,
                                                   pc=pc)
    elif mode == "extend":
        raise ValueError("mode='extend' requires a paged cache")
    elif mode == "decode":
        pos = positions  # (B,)
        if mixer in ATTN_KINDS:
            out, new_cache = attn.decode_attention(lp["mixer"], cfg, mixer, h, pos,
                                                   cache_layer, pc=pc)
        elif mixer == "mla":
            out, new_cache = mla_mod.mla_decode(lp["mixer"], cfg, h, pos, cache_layer)
        elif mixer == "mamba":
            out, new_cache = mam.mamba_decode(lp["mixer"], cfg, h, cache_layer)
        elif mixer == "mlstm":
            out, new_cache = xl.mlstm_decode(lp["mixer"], cfg, h, cache_layer)
        elif mixer == "slstm":
            out, new_cache = xl.slstm_decode(lp["mixer"], cfg, h, cache_layer)
        else:
            raise ValueError(mixer)
        # preserve cross-attention entries (ck/cv/c_len) the mixer didn't touch
        if isinstance(cache_layer, dict):
            new_cache = {**cache_layer, **new_cache}
    else:
        want_cache = mode == "prefill"
        if mixer in ATTN_KINDS:
            out, kv = attn.attention_forward(lp["mixer"], cfg, mixer, h, positions,
                                             mask_kind=mask_kind,
                                             return_kv=want_cache, pc=pc)
            if want_cache:
                new_cache = attn.fill_cache_from_prefill(
                    cfg, mixer, kv[0], kv[1], positions, cache_max_len)
        elif mixer == "mla":
            out, ckv = mla_mod.mla_forward(lp["mixer"], cfg, h, positions,
                                           return_kv=want_cache)
            if want_cache:
                new_cache = mla_mod.mla_fill_cache_from_prefill(
                    cfg, ckv[0], ckv[1], positions, cache_max_len)
        elif mixer == "mamba":
            out, st = mam.mamba_forward(lp["mixer"], cfg, h, return_state=want_cache)
            if want_cache:
                new_cache = st
        elif mixer == "mlstm":
            out, st = xl.mlstm_forward(lp["mixer"], cfg, h, return_state=want_cache)
            if want_cache:
                new_cache = st
        elif mixer == "slstm":
            out, st = xl.slstm_forward(lp["mixer"], cfg, h, return_state=want_cache)
            if want_cache:
                new_cache = st
        else:
            raise ValueError(mixer)

    x = x + out

    # cross-attention (enc-dec decoder layers)
    if "cross" in lp:
        hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        if mode == "decode":
            B = hc.shape[0]
            H, hd = cfg.num_heads, cfg.head_dim
            q = (hc @ lp["cross"]["wq"]).reshape(B, 1, H, hd)
            k = new_cache["ck"]  # (B, Skv, K, hd) — _attend handles GQA
            v = new_cache["cv"]
            Skv = k.shape[1]
            mask = (jnp.arange(Skv, dtype=jnp.int32)[None, None, :]
                    < new_cache["c_len"][:, None, None])
            scale = cfg.attn_scale or 1.0 / (hd ** 0.5)
            out_c = attn._attend(q, k, v, mask, scale, cfg.attn_logit_softcap)
            out_c = out_c.reshape(B, 1, H * hd) @ lp["cross"]["wo"]
        else:
            out_c, ckv = attn.attention_forward(
                lp["cross"], cfg, "attn", hc, positions, kv_override=enc_out,
                return_kv=(mode == "prefill"))
            if mode == "prefill":
                B, Skv = enc_out.shape[0], enc_out.shape[1]
                ck = ckv[0]
                cv = ckv[1]
                pad = cache_max_len - Skv
                if pad > 0:
                    ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
                new_cache = dict(new_cache)
                new_cache["ck"], new_cache["cv"] = ck, cv
                new_cache["c_len"] = jnp.full((B,), Skv, jnp.int32)
        x = x + out_c

    # FFN
    if spec.ffn == "dense":
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + ffn_forward(lp["ffn"], h2, cfg.act)
    elif spec.ffn == "moe":
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        gm = lp["moe"].get("group_map")
        act_shard = None
        ep_axis = None
        if pc is not None:
            from repro.parallel.sharding import _mesh_in_context

            if pc.ep and pc.tp_axis is not None:
                # expert parallelism: the ragged/pallas paths switch to the
                # shard_map EP forward (replicated routing, shard-local
                # expert GEMMs — see repro.parallel.sharding module docs);
                # capacity mode keeps its GSPMD constraint. Set even with
                # no mesh in context: moe_forward raises there rather than
                # silently running the divergent GSPMD path on EP-sharded
                # weights.
                ep_axis = pc.tp_axis
            if _mesh_in_context():
                if mode == "decode":
                    # decode: token batch is tiny (B*k rows) — REPLICATE the
                    # expert batch so the expert weights stay fully
                    # (d x f)-sharded and each device reads params/n_chips
                    # bytes; the d-contraction partial sums psum a few MB.
                    # (Leaving it unconstrained made GSPMD all-gather every
                    # expert weight per device: 445 GB/step measured.)
                    act_shard = (None, None)
                else:
                    # train/prefill: (batch axis, expert axis); expert dim
                    # shards over tp under expert parallelism (dispatch
                    # gathers become the canonical MoE all-to-all)
                    act_shard = (pc.dp, pc.tp_axis if pc.ep else None)
        out_m, moe_aux = moe_forward(
            lp["moe"], cfg, h2, group_map=gm, mode=moe_mode,
            capture_stats=capture_stats, act_shard=act_shard,
            ep_axis=ep_axis, dp_axes=(pc.dp_axes if pc is not None else ()))
        x = x + out_m
        aux.update(moe_aux)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack application (scan over blocks)
# ---------------------------------------------------------------------------


def apply_stack(params, cfg, x, positions, *, mode: str,
                cache=None, cache_max_len: int = 0,
                moe_mode: str = "ragged", capture_stats: bool = False,
                enc_out: Optional[jax.Array] = None,
                mask_kind: str = "causal", remat: str = "full",
                unroll: bool = False, pc=None, paged=None):
    """x: (B,S,d) hidden states (post-embedding). Returns
    (x, new_cache, aux) where aux aggregates MoE losses and optional stats."""

    prefix_specs = tuple(
        type(cfg.pattern[0])(mixer=cfg.pattern[0].mixer, ffn="dense")
        for _ in range(cfg.first_dense_layers))

    new_prefix_cache = []
    total_lb = jnp.zeros((), jnp.float32)
    total_z = jnp.zeros((), jnp.float32)

    for i, spec in enumerate(prefix_specs):
        cl = cache["prefix"][i] if cache is not None else None
        x, nc, aux = apply_layer(
            params["prefix"][i], cfg, spec, x, positions, mode=mode,
            cache_layer=cl, cache_max_len=cache_max_len, moe_mode=moe_mode,
            capture_stats=capture_stats, enc_out=enc_out, mask_kind=mask_kind,
            pc=pc, paged=paged)
        new_prefix_cache.append(nc)
        total_lb += aux.get("lb_loss", 0.0)
        total_z += aux.get("z_loss", 0.0)

    seq_constraint = None
    if (pc is not None and getattr(pc, "seq_shard", False)
            and mode == "train"):
        from repro.parallel.sharding import _mesh_in_context

        if _mesh_in_context():
            from jax.sharding import PartitionSpec as _P

            seq_constraint = _P(pc.dp, pc.tp_axis, None)

    def block_body(carry, scanned):
        xx, lb, zz = carry
        block_params, cache_slices = scanned
        new_cache_slices = []
        stats_out = []
        for i, spec in enumerate(cfg.pattern):
            cl = cache_slices[i] if cache_slices is not None else None
            xx, nc, aux = apply_layer(
                block_params[f"layer{i}"], cfg, spec, xx, positions, mode=mode,
                cache_layer=cl, cache_max_len=cache_max_len, moe_mode=moe_mode,
                capture_stats=capture_stats, enc_out=enc_out,
                mask_kind=mask_kind, pc=pc, paged=paged)
            if seq_constraint is not None:
                # sequence parallelism: the residual stream lives sharded
                # over (dp, tp); GSPMD turns the post-block all-reduce into
                # reduce-scatter + all-gather and norms run on seq shards
                xx = jax.lax.with_sharding_constraint(xx, seq_constraint)
            new_cache_slices.append(nc)
            lb = lb + aux.get("lb_loss", 0.0)
            zz = zz + aux.get("z_loss", 0.0)
            if capture_stats and spec.ffn == "moe":
                stats_out.append(aux["stats"])
        ys = (tuple(new_cache_slices) if cache_slices is not None or mode == "prefill"
              else None,
              tuple(stats_out) if capture_stats else None)
        return (xx, lb, zz), ys

    body = block_body
    # prevent_cse=False is only safe under a rolled scan (loop boundaries
    # already block CSE); with an unrolled body XLA would CSE the remat
    # recomputation against the forward pass and retain every activation
    # (measured +3.4 GiB/layer on the dry-run).
    if mode == "train" and remat == "full":
        body = jax.checkpoint(block_body, prevent_cse=unroll)
    elif mode == "train" and remat == "dots":
        body = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.checkpoint_dots,
            prevent_cse=unroll)

    cache_xs = cache["blocks"] if cache is not None else None
    if mode == "prefill" and cache_xs is None:
        cache_xs = None  # prefill builds caches; scanned input is params only

    # unroll=True is used by the dry-run so cost_analysis counts every layer
    # (XLA's HloCostAnalysis does not multiply while-loop bodies by trip
    # count); training keeps the rolled scan for compile-time economy.
    (x, total_lb, total_z), ys = jax.lax.scan(
        body, (x, total_lb, total_z), (params["blocks"], cache_xs),
        unroll=cfg.num_blocks if unroll else 1)

    new_cache = None
    if mode in ("prefill", "decode", "extend"):
        new_blocks = ys[0]
        if mode == "extend":
            # paged multi-token step: only the VALID rows advanced the slot
            new_pos = paged["pos"] + paged["valid"]
        elif mode == "decode":
            new_pos = positions + 1
        else:
            new_pos = positions[:, -1] + 1
        new_cache = {
            "pos": new_pos,
            "prefix": tuple(new_prefix_cache),
            "blocks": new_blocks,
        }
        if paged is not None:
            # shared paged-KV metadata rides at the cache top level: kv_pos
            # was updated once for this step (model.extend), the page table
            # is host-managed and passes through unchanged
            new_cache["kv_pos"] = paged["kv_pos"]
            new_cache["page_table"] = paged["page_table"]
    aux = {"lb_loss": total_lb, "z_loss": total_z}
    if capture_stats:
        aux["stats"] = ys[1]
        # prefix-layer MoE stats would go here; all assigned archs have dense
        # prefix layers, so none arise.
    return x, new_cache, aux
