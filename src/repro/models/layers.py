"""Shared primitive layers: RMSNorm, rotary embeddings, activations, embed."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d):
    # stored as delta from 1.0 (gemma-style); rms_norm adds 1.0 back
    return jnp.zeros((d,), jnp.float32)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def softcap(x, cap: float):
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype):
    scale = 1.0 / (d ** 0.5)
    return (jax.random.normal(key, (vocab, d), jnp.float32) * scale).astype(dtype)


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(h, table_or_head, transpose: bool):
    """h: (..., d) -> logits (..., V). transpose=True when reusing the
    embedding table (V, d)."""
    w = table_or_head
    if transpose:
        return jnp.einsum("...d,vd->...v", h, w)
    return jnp.einsum("...d,dv->...v", h, w)


def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    scale = 1.0 / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
