"""Atomic, keep-k, mesh-agnostic checkpointing — and MergePlan persistence.

Arrays are saved as *full* (unsharded) host numpy arrays keyed by their
pytree path, plus a small JSON manifest — so a checkpoint written under one
mesh restores under ANY mesh shape (elastic scaling: the restore path simply
``jax.device_put``s with the new sharding). Writes go to a temp dir that is
atomically renamed; a crash mid-write never corrupts the latest checkpoint.
Includes the data-pipeline step so training resumes bit-exact.

:func:`save_plan` / :func:`load_plan` persist a
:class:`repro.core.plan.MergePlan` with the same discipline: a human-
readable ``plan.json`` manifest (provenance: spec, method, expert/layer
counts, feature hashes) next to a ``plan.npz`` holding the per-layer arrays
(labels, combine matrices, hidden maps, keep masks, frequencies) with their
exact dtypes — a reloaded plan applies bit-identically to the in-memory one.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        items[key] = leaf
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, trees: dict):
        """trees: {"params": ..., "opt": ..., "meta": {...json-able...}}"""
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            arrays = {}
            manifest = {"step": step, "groups": {}}
            for group, tree in trees.items():
                if group == "meta":
                    manifest["meta"] = tree
                    continue
                items, _ = _flatten(tree)
                keys = []
                for k, v in items.items():
                    if v is None:
                        continue
                    arrays[f"{group}::{k}"] = np.asarray(v)
                    keys.append(k)
                manifest["groups"][group] = keys
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return os.path.join(self.dir, f"step_{step:08d}")

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict, step: Optional[int] = None,
                shardings: Optional[dict] = None):
        """templates: {"params": pytree-of-arrays-or-SDS, ...}. Returns the
        same structure with loaded values (placed per ``shardings`` when
        given — this is the elastic-mesh path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for group, tpl in templates.items():
            if group == "meta":
                out[group] = manifest.get("meta", {})
                continue
            items, treedef = _flatten(tpl)
            leaves = []
            shard_items = None
            if shardings and group in shardings:
                shard_items, _ = _flatten(shardings[group])
            for k, tpl_leaf in items.items():
                if tpl_leaf is None:
                    leaves.append(None)
                    continue
                arr = data[f"{group}::{k}"]
                val = arr.astype(tpl_leaf.dtype) if hasattr(tpl_leaf, "dtype") else arr
                if shard_items is not None and k in shard_items:
                    val = jax.device_put(val, shard_items[k])
                else:
                    val = jax.numpy.asarray(val)
                leaves.append(val)
            out[group] = jax.tree_util.tree_unflatten(treedef, leaves)
        return out, step


# ---------------------------------------------------------------------------
# MergePlan persistence (JSON manifest + npz arrays, atomic directory)
# ---------------------------------------------------------------------------


def save_plan(directory: str, plan) -> str:
    """Persist a :class:`repro.core.plan.MergePlan` to ``directory``
    (created; atomic temp-dir rename like checkpoints). Returns the path."""
    from repro.core.plan import LAYER_ARRAY_FIELDS, PLAN_FORMAT_VERSION

    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".tmp_plan_")
    try:
        arrays = {}
        manifest = {
            "format": "repro.merge_plan",
            "version": PLAN_FORMAT_VERSION,
            "kind": plan.kind,
            "method": plan.method,
            "spec": plan.spec,
            "num_experts": plan.num_experts,
            "num_layers": plan.num_layers,
            "slots": plan.slots,
            "default_executor": plan.default_executor,
            "layers": [],
        }
        for i, lp in enumerate(plan.layers):
            entry = {"pattern_pos": lp.pattern_pos, "block": lp.block,
                     "target": lp.target, "feature_hash": lp.feature_hash,
                     "arrays": {}}
            for name in LAYER_ARRAY_FIELDS:
                val = getattr(lp, name)
                if val is None:
                    continue
                key = f"{name}_{i}"
                arrays[key] = np.asarray(val)
                entry["arrays"][name] = key
            manifest["layers"].append(entry)
        np.savez(os.path.join(tmp, "plan.npz"), **arrays)
        with open(os.path.join(tmp, "plan.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        if os.path.exists(directory):
            # never destroy the existing plan before the replacement is in
            # place: move it aside, rename the new dir in, then delete — a
            # crash at any point leaves at least one intact copy on disk
            backup = tempfile.mkdtemp(dir=parent, prefix=".tmp_plan_old_")
            os.rename(directory, os.path.join(backup, "plan"))
            os.rename(tmp, directory)
            shutil.rmtree(backup, ignore_errors=True)
        else:
            os.rename(tmp, directory)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return directory


def load_plan(directory: str):
    """Reload a plan saved by :func:`save_plan`. Arrays come back with
    their exact saved dtypes, so applying a reloaded plan is bit-identical
    to applying the in-memory one."""
    from repro.core.plan import PLAN_FORMAT_VERSION, LayerPlan, MergePlan

    with open(os.path.join(directory, "plan.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != "repro.merge_plan":
        raise ValueError(f"{directory}: not a merge-plan directory")
    if manifest.get("version", 0) > PLAN_FORMAT_VERSION:
        raise ValueError(
            f"{directory}: plan format v{manifest.get('version')} is newer "
            f"than this build (v{PLAN_FORMAT_VERSION})")
    data = np.load(os.path.join(directory, "plan.npz"))
    layers = []
    for entry in manifest["layers"]:
        kw = {name: data[key] for name, key in entry["arrays"].items()}
        layers.append(LayerPlan(pattern_pos=entry["pattern_pos"],
                                block=entry["block"], target=entry["target"],
                                feature_hash=entry.get("feature_hash"),
                                **kw))
    return MergePlan(kind=manifest["kind"], method=manifest["method"],
                     spec=manifest["spec"],
                     num_experts=manifest["num_experts"],
                     num_layers=manifest["num_layers"],
                     slots=manifest["slots"], layers=layers,
                     default_executor=manifest["default_executor"])
