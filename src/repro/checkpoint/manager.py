"""Atomic, keep-k, mesh-agnostic checkpointing.

Arrays are saved as *full* (unsharded) host numpy arrays keyed by their
pytree path, plus a small JSON manifest — so a checkpoint written under one
mesh restores under ANY mesh shape (elastic scaling: the restore path simply
``jax.device_put``s with the new sharding). Writes go to a temp dir that is
atomically renamed; a crash mid-write never corrupts the latest checkpoint.
Includes the data-pipeline step so training resumes bit-exact.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        items[key] = leaf
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, trees: dict):
        """trees: {"params": ..., "opt": ..., "meta": {...json-able...}}"""
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            arrays = {}
            manifest = {"step": step, "groups": {}}
            for group, tree in trees.items():
                if group == "meta":
                    manifest["meta"] = tree
                    continue
                items, _ = _flatten(tree)
                keys = []
                for k, v in items.items():
                    if v is None:
                        continue
                    arrays[f"{group}::{k}"] = np.asarray(v)
                    keys.append(k)
                manifest["groups"][group] = keys
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return os.path.join(self.dir, f"step_{step:08d}")

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict, step: Optional[int] = None,
                shardings: Optional[dict] = None):
        """templates: {"params": pytree-of-arrays-or-SDS, ...}. Returns the
        same structure with loaded values (placed per ``shardings`` when
        given — this is the elastic-mesh path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for group, tpl in templates.items():
            if group == "meta":
                out[group] = manifest.get("meta", {})
                continue
            items, treedef = _flatten(tpl)
            leaves = []
            shard_items = None
            if shardings and group in shardings:
                shard_items, _ = _flatten(shardings[group])
            for k, tpl_leaf in items.items():
                if tpl_leaf is None:
                    leaves.append(None)
                    continue
                arr = data[f"{group}::{k}"]
                val = arr.astype(tpl_leaf.dtype) if hasattr(tpl_leaf, "dtype") else arr
                if shard_items is not None and k in shard_items:
                    val = jax.device_put(val, shard_items[k])
                else:
                    val = jax.numpy.asarray(val)
                leaves.append(val)
            out[group] = jax.tree_util.tree_unflatten(treedef, leaves)
        return out, step
