from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, load_plan, save_plan)
