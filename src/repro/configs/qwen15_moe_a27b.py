"""qwen1.5-moe-a2.7b — the paper's second evaluation model.

60 experts top-4 + 4 shared per layer, 24L. HC-SMoE reduces 60 -> 45 -> 30
-> 23 -> 15.
"""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen1.5-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,
    vocab_size=151936,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        expert_ffn_dim=1408,
        num_shared_experts=4,
        shared_expert_ffn_dim=1408,
        router_mode="softmax_all",
    ),
    rope_theta=1_000_000.0,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
