"""minitron-8b [dense] — pruned Nemotron, arXiv:2407.14679."""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10_000.0,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
