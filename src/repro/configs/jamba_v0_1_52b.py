"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

arXiv:2403.19887. Period-8 block with attention at index 4 (1 attn : 7 mamba)
and MoE FFN on every second layer (e=2): [Md, Mmoe, Md, Mmoe, Ad, Mmoe, Md,
Mmoe] x 4 = 32 layers. Mamba state is O(1)/token and attention is 1/8 of
layers -> long_500k RUNS (KV for 4 attn layers at kv=8 shards over 'model').
"""
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig, MoEConfig

_PAT = (
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=_PAT,
    moe=MoEConfig(num_experts=16, top_k=2, expert_ffn_dim=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0,
)
