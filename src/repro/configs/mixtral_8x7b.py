"""mixtral-8x7b — the paper's primary evaluation model (arXiv:2401.04088).

8 experts top-2 per layer, 32L. HC-SMoE reduces 8 -> 6 -> 4 -> 3 -> 2.
"""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(num_experts=8, top_k=2, expert_ffn_dim=14336,
                  router_mode="softmax_topk"),
    rope_theta=1_000_000.0,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
