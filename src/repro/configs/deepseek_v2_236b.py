"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

arXiv:2405.04434. Layer 0 dense (d_ff 12288), layers 1..59 MoE with
160 routed experts (d_ff 1536, top-6) + 2 shared experts. MLA attention:
q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128, 128 heads.
"""
from repro.configs.base import (
    FULL_ATTN_500K_SKIP, LayerSpec, MLAConfig, ModelConfig, MoEConfig)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,                   # nominal; MLA dims below are authoritative
    d_ff=12288,                     # dense prefix layer FFN
    vocab_size=102400,
    pattern=(LayerSpec("mla", "moe"),),
    first_dense_layers=1,
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        expert_ffn_dim=1536,
        num_shared_experts=2,
        shared_expert_ffn_dim=1536,
        router_mode="softmax_all",
        routed_scaling_factor=16.0,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    rope_theta=10_000.0,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
