"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal (arXiv:2308.11596).

Assignment lists 24L: read as 24 encoder + 24 decoder layers (DESIGN.md §5).
Speech/modality frontend is a STUB: the encoder consumes pre-computed frame
embeddings of shape (B, T_src, d_model); the decoder owns the 256206-entry
token embedding. GQA kv=16 == MHA at 16 heads.
"""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    pattern=(LayerSpec("attn", "dense"),),
    encoder_pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10_000.0,
    frontend_stub=True,
    act="gelu",
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
