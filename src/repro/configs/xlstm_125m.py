"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

12 blocks in the xLSTM[7:1] spirit: pattern period 6 = 5x mLSTM + 1x sLSTM,
repeated twice. d_ff=0 -> no post-mixer FFN (xLSTM blocks carry their own
up/down projections). Recurrent state is O(1) per token -> long_500k RUNS.
"""
from repro.configs.base import LayerSpec, ModelConfig, XLSTMConfig

_PAT = tuple(
    LayerSpec("mlstm", "none") for _ in range(5)
) + (LayerSpec("slstm", "none"),)

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    pattern=_PAT,
    xlstm=XLSTMConfig(num_heads=4, chunk_size=128),
    tie_embeddings=True,
)
