"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

arXiv:2408.00118. head_dim 256 with 8 query / 4 kv heads (q_dim 2048 != d_model).
Global layers are full attention -> long_500k skipped (see DESIGN.md §5).
"""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=(LayerSpec("attn_local", "dense"), LayerSpec("attn_global", "dense")),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
