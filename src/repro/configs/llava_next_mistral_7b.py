"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres patch-embed stub.

hf:llava-hf/llava-v1.6-mistral-7b-hf. The vision tower/projector is a STUB per
the assignment: ``input_specs()`` supplies 2880 pre-computed patch embeddings
(anyres 5 tiles x 576) that are prepended to the text token embeddings.
Mistral v0.2 semantics: full attention (no sliding window) -> long_500k skip.
"""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=1_000_000.0,
    num_patch_tokens=2880,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
