"""granite-3-2b [dense] — hf:ibm-granite/granite-3.0-2b-base (GQA)."""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10_000.0,
    tie_embeddings=True,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
