"""Config dataclasses for the repro framework.

Every architecture is described by a :class:`ModelConfig`.  Depth is expressed
as ``first_dense_layers`` unrolled prefix layers followed by a repeating
``pattern`` of :class:`LayerSpec` entries that is scanned over
(``num_layers - first_dense_layers`` must be divisible by ``len(pattern)``).
This keeps the lowered HLO size independent of depth, which is what makes the
512-device dry-run of 60-layer models tractable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Sparse mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    expert_ffn_dim: int
    num_shared_experts: int = 0
    shared_expert_ffn_dim: int = 0
    # "softmax_topk": softmax over the k selected logits (Eq. 3 of the paper,
    # Mixtral-style). "softmax_all": softmax over all logits then select
    # (Qwen/DeepSeek-style). Both are supported; merging is agnostic.
    router_mode: str = "softmax_topk"
    routed_scaling_factor: float = 1.0

    @property
    def params_per_expert_factor(self) -> int:
        return 3  # gate, up, down


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block (Jamba-style)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block family (sLSTM / mLSTM)."""

    num_heads: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv1d_kernel: int = 4
    chunk_size: int = 128  # chunkwise-parallel training form


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating block pattern.

    mixer: attn | attn_local | attn_global | mla | mamba | mlstm | slstm
    ffn:   dense | moe | none
    """

    mixer: str = "attn"
    ffn: str = "dense"


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    first_dense_layers: int = 0

    # attention flavour
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # used by attn_local; 0 = full
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    attn_scale: Optional[float] = None  # None -> 1/sqrt(head_dim)
    # attention compute backend (mirrors the MoE ``mode=`` convention):
    #   "jnp"    — pure-jnp grouped-einsum attention (train + inference)
    #   "pallas" — Pallas kernels on the inference hot paths: flash-decode
    #              (kernels/flash_decode.py) for every decode step and blocked
    #              flash attention (kernels/flash_attention.py) for causal
    #              full-window prefill; non-eligible layers (cross-attention,
    #              sliding-window/softcapped prefill) fall back to jnp.
    #              Inference-only: no VJP is defined for the kernels.
    attn_impl: str = "jnp"
    # serving KV-cache knobs (consumed by ServingEngine defaults):
    #   kv_page_size  — rows per physical page of the PAGED KV layout
    #                   (kv_layout="paged"); 128 matches the flash-decode
    #                   KV tile so one page == one kernel grid tile on TPU.
    #   prefill_chunk — chunked-prefill threshold AND chunk length: prompts
    #                   longer than this are prefilled chunk-by-chunk,
    #                   interleaved with decode steps of the running batch
    #                   (paged layout only). 0 disables chunking.
    kv_page_size: int = 128
    prefill_chunk: int = 0

    # FFN
    act: str = "silu"  # silu | gelu

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # encoder-decoder (seamless): encoder_layers scanned separately
    encoder_layers: int = 0
    encoder_pattern: Tuple[LayerSpec, ...] = ()

    # VLM stub: number of pre-computed patch-embedding tokens prepended
    num_patch_tokens: int = 0
    # encdec stub: source side consumes pre-computed frame embeddings
    frontend_stub: bool = False

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # which shapes this arch skips, with reasons (recorded in the dry-run)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    # ------------------------------------------------------------------
    # derived helpers
    # ------------------------------------------------------------------
    def __post_init__(self):
        body = self.num_layers - self.first_dense_layers
        if body % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers-first_dense ({body}) not divisible "
                f"by pattern length {len(self.pattern)}"
            )
        if self.encoder_layers and self.encoder_pattern:
            if self.encoder_layers % len(self.encoder_pattern) != 0:
                raise ValueError(f"{self.name}: encoder pattern mismatch")
        if self.attn_impl not in ("jnp", "pallas"):
            raise ValueError(
                f"{self.name}: attn_impl must be 'jnp' or 'pallas', got "
                f"{self.attn_impl!r}")
        if self.kv_page_size < 1:
            raise ValueError(
                f"{self.name}: kv_page_size must be >= 1, got "
                f"{self.kv_page_size}")
        if self.prefill_chunk < 0:
            raise ValueError(
                f"{self.name}: prefill_chunk must be >= 0, got "
                f"{self.prefill_chunk}")

    @property
    def num_blocks(self) -> int:
        return (self.num_layers - self.first_dense_layers) // len(self.pattern)

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding shards
        over any TP degree (seamless 256206 / granite 49155 are odd)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Fully unrolled layer list (for reference / param counting)."""
        prefix = tuple(
            LayerSpec(mixer=self.pattern[0].mixer, ffn="dense")
            for _ in range(self.first_dense_layers)
        )
        return prefix + self.pattern * self.num_blocks

    # -------------------------- param counting ------------------------
    def _attn_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.mixer == "mla":
            m = self.mla
            h = self.num_heads
            p = d * m.q_lora_rank + m.q_lora_rank * h * m.qk_head_dim
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
            p += h * m.v_head_dim * d
            return p
        if spec.mixer in ("attn", "attn_local", "attn_global"):
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if spec.mixer == "mamba":
            mc = self.mamba
            d_in = mc.expand * d
            dt_rank = mc.dt_rank or -(-d // 16)
            p = d * 2 * d_in                       # in_proj
            p += d_in * mc.d_conv                  # conv
            p += d_in * (dt_rank + 2 * mc.d_state)  # x_proj
            p += dt_rank * d_in + d_in             # dt_proj
            p += d_in * mc.d_state + d_in          # A_log, D
            p += d_in * d                          # out_proj
            return p
        if spec.mixer == "mlstm":
            xc = self.xlstm
            d_in = int(xc.mlstm_proj_factor * d)
            p = d * 2 * d_in                     # up proj (x and gate paths)
            p += 3 * d_in * d_in // xc.num_heads  # q,k,v per-head? (dense here)
            p = d * 2 * d_in + 3 * d_in * d_in + 3 * d_in + d_in * d
            return p
        if spec.mixer == "slstm":
            xc = self.xlstm
            p = 4 * d * d + 4 * d * (d // xc.num_heads)  # input + recurrent (block-diag)
            d_up = int(xc.slstm_proj_factor * d)
            p += 2 * d * d_up + d_up * d
            return p
        raise ValueError(spec.mixer)

    def _ffn_params(self, spec: LayerSpec) -> Tuple[int, int]:
        """(total, active) FFN params for one layer."""
        d = self.d_model
        if spec.ffn == "dense":
            n = 3 * d * self.d_ff
            return n, n
        if spec.ffn == "moe":
            m = self.moe
            per = 3 * d * m.expert_ffn_dim
            shared = m.num_shared_experts * 3 * d * m.shared_expert_ffn_dim
            router = d * m.num_experts
            total = m.num_experts * per + shared + router
            active = m.top_k * per + shared + router
            return total, active
        return 0, 0

    def param_counts(self) -> Tuple[int, int]:
        """Returns (total_params, active_params)."""
        d = self.d_model
        total = active = self.padded_vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab_size * d
            active += self.padded_vocab_size * d
        for spec in self.layer_specs():
            a = self._attn_params(spec)
            t_f, a_f = self._ffn_params(spec)
            norms = 2 * d
            total += a + t_f + norms
            active += a + a_f + norms
        if self.encoder_layers:
            enc_pat = self.encoder_pattern or (LayerSpec(),)
            for i in range(self.encoder_layers):
                spec = enc_pat[i % len(enc_pat)]
                a = self._attn_params(spec)
                t_f, a_f = self._ffn_params(spec)
                total += a + t_f + 2 * d
                active += a + a_f + 2 * d
            # cross attention in every decoder layer
            ca = self.num_layers * (self.d_model * self.q_dim
                                    + 2 * self.d_model * self.kv_dim
                                    + self.q_dim * self.d_model)
            total += ca
            active += ca
        return total, active

    def model_flops_per_token(self) -> float:
        """6*N_active per token (training fwd+bwd), the MODEL_FLOPS convention."""
        _, active = self.param_counts()
        return 6.0 * active

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=self.first_dense_layers + len(self.pattern),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=96 if self.d_ff else 0,
            vocab_size=503,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                expert_ffn_dim=32,
                shared_expert_ffn_dim=32 if self.moe.num_shared_experts else 0,
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16)
        if self.encoder_layers:
            changes["encoder_layers"] = max(1, len(self.encoder_pattern) or 1)
        if self.num_patch_tokens:
            changes["num_patch_tokens"] = 8
        changes["name"] = self.name + "-smoke"
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

FULL_ATTN_500K_SKIP = (
    "long_500k",
    "pure full-attention arch: 500k decode requires sub-quadratic mixer (see DESIGN.md)",
)
