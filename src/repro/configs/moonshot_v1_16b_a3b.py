"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B-style 64e top-6.

hf:moonshotai/Moonlight-16B-A3B (DeepSeek-MoE style): layer 0 dense
(d_ff 5632), layers 1..47 MoE with 64 routed experts (d_ff 1408, top-6)
+ 2 shared experts (1408 each). HC-SMoE primary target class.
"""
from repro.configs.base import FULL_ATTN_500K_SKIP, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,                      # dense prefix layer FFN
    vocab_size=163840,
    pattern=(LayerSpec("attn", "moe"),),
    first_dense_layers=1,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_ffn_dim=1408,
        num_shared_experts=2,
        shared_expert_ffn_dim=1408,
        router_mode="softmax_all",
        routed_scaling_factor=2.446,
    ),
    rope_theta=50_000.0,
    skip_shapes=(FULL_ATTN_500K_SKIP,),
)
