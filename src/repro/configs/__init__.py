"""Architecture registry + per-(arch, shape) input specs.

``get_config(arch_id)`` returns the full published config; ``--arch`` ids use
the assignment spelling (e.g. ``deepseek-v2-236b``). ``input_specs`` builds
``jax.ShapeDtypeStruct`` stand-ins — weak-type-correct, shardable, zero
allocation — for the dry-run and any ``.lower()`` call.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (  # noqa: F401
    FULL_ATTN_500K_SKIP,
    LayerSpec,
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    XLSTMConfig,
)

_MODULES = {
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "minitron-8b": "repro.configs.minitron_8b",
    # the paper's own evaluation models
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "qwen1.5-moe-a2.7b": "repro.configs.qwen15_moe_a27b",
}

ASSIGNED_ARCHS = tuple(list(_MODULES)[:10])
ALL_ARCHS = tuple(_MODULES)

_CACHE: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    if arch not in _CACHE:
        import importlib

        _CACHE[arch] = importlib.import_module(_MODULES[arch]).CONFIG
    return _CACHE[arch]


def shape_skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    for name, reason in cfg.skip_shapes:
        if name == shape_name:
            return reason
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, dtype=None):
    """ShapeDtypeStruct inputs for train_step / prefill_step / decode_step.

    Returned dict matches the keyword signature of the corresponding step
    function in ``repro.launch``/``repro.models.model``.
    """
    import jax
    import jax.numpy as jnp

    dtype = dtype or jnp.bfloat16
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        if cfg.family == "encdec":
            # src frames consume half the budget, target tokens the other half
            s_src, s_tgt = S // 2, S // 2
            return {
                "src_frames": jax.ShapeDtypeStruct((B, s_src, cfg.d_model), dtype),
                "tokens": tok(B, s_tgt),
                "labels": tok(B, s_tgt),
            }
        if cfg.family == "vlm":
            n_img = cfg.num_patch_tokens
            return {
                "patch_embeds": jax.ShapeDtypeStruct((B, n_img, cfg.d_model), dtype),
                "tokens": tok(B, S - n_img),
                "labels": tok(B, S - n_img),
            }
        return {"tokens": tok(B, S), "labels": tok(B, S)}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            s_src, s_tgt = S // 2, S // 2
            return {
                "src_frames": jax.ShapeDtypeStruct((B, s_src, cfg.d_model), dtype),
                "tokens": tok(B, s_tgt),
            }
        if cfg.family == "vlm":
            n_img = cfg.num_patch_tokens
            return {
                "patch_embeds": jax.ShapeDtypeStruct((B, n_img, cfg.d_model), dtype),
                "tokens": tok(B, S - n_img),
            }
        return {"tokens": tok(B, S)}

    if shape.kind == "decode":
        from repro.models.kvcache import cache_specs

        specs = {
            "tokens": tok(B, 1),
            "cache": cache_specs(cfg, batch=B, max_len=S, dtype=dtype),
        }
        if cfg.family == "encdec":
            # decoding against an encoded source of length S
            specs["enc_out"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        return specs

    raise ValueError(shape.kind)
