from repro.parallel.sharding import (  # noqa: F401
    ParallelConfig,
    batch_pspecs,
    cache_pspecs,
    cache_pspecs_sized,
    param_pspecs,
)
