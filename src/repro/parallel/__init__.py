from repro.parallel.sharding import (  # noqa: F401
    ParallelConfig,
    batch_pspecs,
    cache_pspecs,
    cache_pspecs_sized,
    expert_param_bytes_per_device,
    get_context_mesh,
    pad_expert_slots,
    param_pspecs,
)
