"""Int8 error-feedback gradient compression for data-parallel all-reduce.

Targets the slow links (pure-DP replicas / the cross-pod 'pod' axis): each
gradient leaf is quantised to int8 with a per-leaf scale, psum'd in int32,
and dequantised; the quantisation residual is fed back into the next step
(error feedback keeps the scheme convergent, 1-bit-Adam style). Wire format
is 1 byte/element + one f32 scale vs 4 (or 2) bytes — a ~4x reduction on the
DCN all-reduce that §Perf's collective term counts.

Used inside ``shard_map`` DP training (repro.training.trainer ddp mode) and
unit-tested for unbiasedness-under-EF + convergence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Q_MAX = 127.0


def quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (int8 values, f32 scale). ``err`` is the running residual."""
    corrected = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(corrected)) / Q_MAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(corrected / scale), -Q_MAX, Q_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g, err, axis_name: str):
    """One leaf: quantise -> psum(int32) -> mean -> dequant -> new residual."""
    q, scale = quantize(g, err)
    n = jax.lax.psum(1, axis_name)
    # int32 accumulate avoids int8 overflow; scale is the max across peers so
    # the dequantised mean is conservative and EF absorbs the rest.
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    g_hat = q_sum.astype(jnp.float32) * scale_max / n
    local_dequant = dequantize(q, scale)
    new_err = (g.astype(jnp.float32) + err) - local_dequant
    return g_hat.astype(g.dtype), new_err


def compressed_psum_grads(grads, err_state, axis_name: str):
    """Tree version. err_state mirrors grads (f32). Returns (grads, errs)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gh, eh = compressed_psum_leaf(g, e, axis_name)
        out_g.append(gh)
        out_e.append(eh)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_state(grads_shape):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)


def compression_wire_bytes(grads) -> Tuple[int, int]:
    """(compressed, uncompressed) bytes per all-reduce round."""
    comp = unc = 0
    for leaf in jax.tree_util.tree_leaves(grads):
        n = 1
        for d in leaf.shape:
            n *= d
        comp += n + 4  # int8 + scale
        unc += n * leaf.dtype.itemsize
    return comp, unc
