"""Version compatibility shims for the jax parallelism APIs.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed ``check_rep`` -> ``check_vma`` along the way; the repo targets
both generations of toolchain, so every internal caller goes through
:func:`shard_map_compat`.
"""
from __future__ import annotations

import inspect

import jax


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old,
    translating the replication-check kwarg between the two spellings."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm  # noqa: N813
    params = inspect.signature(sm).parameters
    kw = {}
    if "check_vma" in params:
        kw["check_vma"] = check_vma
    elif "check_rep" in params:
        kw["check_rep"] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
