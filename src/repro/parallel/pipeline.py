"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Optional parallelism mode for uniform-pattern decoder stacks: the scanned
block stack (n_blocks, ...) is sharded over a 'stage' mesh axis; microbatches
ripple through stages with ``collective_permute`` between neighbours. Bubble
fraction = (S-1)/(M+S-1) for S stages / M microbatches — picked so the
collective term trades against the FSDP all-gathers it replaces.

This is an optional beyond-baseline mode (exercised by the multi-device
subprocess tests); the dry-run baseline uses FSDP×TP which XLA overlaps well.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map_compat


def pipeline_forward(block_fn, stacked_params, x_micro, *, stage_axis: str,
                     n_stages: int):
    """Run a uniform block stack as a pipeline inside ``shard_map``.

    block_fn(params_slice, x) -> x : applies this stage's blocks (a scan over
    the local slice). stacked_params: local (n_blocks/S, ...) slice.
    x_micro: (M, mb, S, d) microbatches, all resident on every stage (they
    flow through the permute ring; only stage 0's input matters).
    """
    stage = jax.lax.axis_index(stage_axis)
    m = x_micro.shape[0]
    total = m + n_stages - 1

    def step(carry, t):
        buf = carry  # (mb, S, d): the activation currently at this stage
        # stage 0 injects microbatch t (when t < m); others use incoming buf
        inject = jnp.where(t < m, jnp.minimum(t, m - 1), 0)
        x_in = jnp.where(stage == 0, x_micro[inject], buf)
        y = block_fn(stacked_params, x_in)
        # pass to next stage
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf_next = jax.lax.ppermute(y, stage_axis, perm)
        return buf_next, y

    buf0 = jnp.zeros_like(x_micro[0])
    _, ys = jax.lax.scan(step, buf0, jnp.arange(total))
    # outputs of the last stage, offset by the pipeline depth
    return ys  # caller selects ys[t] at t = micro_idx + (n_stages-1) on last stage


def make_pipelined_stack(cfg, mesh: Mesh, stage_axis: str = "model"):
    """Builds a pipelined forward for a uniform-pattern decoder-only stack.

    Returns fn(params_blocks, x (B,S,d), positions) -> x. Requires
    len(cfg.pattern) == 1 and n_blocks % n_stages == 0.
    """
    from repro.models.transformer import apply_layer

    assert len(cfg.pattern) == 1, "pipeline mode supports uniform stacks"
    n_stages = mesh.shape[stage_axis]
    assert cfg.num_blocks % n_stages == 0

    spec = cfg.pattern[0]

    def local_blocks(params_slice, x, positions):
        def body(xx, lp):
            y, _, _ = apply_layer(lp["layer0"], cfg, spec, xx, positions,
                                  mode="train")
            return y, None

        x, _ = jax.lax.scan(body, x, params_slice)
        return x

    def forward(params_blocks, x, positions, n_micro: int = 4):
        B, S, d = x.shape
        assert B % n_micro == 0
        mb = B // n_micro

        @functools.partial(
            shard_map_compat, mesh=mesh,
            in_specs=(P(stage_axis), P(None), P(None)),
            out_specs=P(None),
            check_vma=False)
        def run(params_slice, xm, pos):
            stage = jax.lax.axis_index(stage_axis)
            m = xm.shape[0]
            total = m + n_stages - 1

            def step(buf, t):
                idx = jnp.clip(t, 0, m - 1)
                x_in = jnp.where(stage == 0, xm[idx], buf)
                y = local_blocks(params_slice, x_in, pos[:mb])
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                return jax.lax.ppermute(y, stage_axis, perm), y

            buf0 = jnp.zeros_like(xm[0])
            _, ys = jax.lax.scan(step, buf0, jnp.arange(total))
            # last stage's outputs at t = micro + n_stages - 1, broadcast back
            outs = ys[n_stages - 1:]
            outs = jax.lax.ppermute(
                outs, stage_axis,
                [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)])
            # after the permute every stage holds the last stage's outputs
            return outs

        xm = x.reshape(n_micro, mb, S, d)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        outs = run(params_blocks, xm, pos)
        return outs.reshape(B, S, d)

    return forward
