"""Sharding rules: params, batches, KV caches -> PartitionSpec pytrees.

Layout (DESIGN.md §6): 2-D **FSDP('data') × TP('model')** within a pod; the
'pod' axis carries pure data parallelism (batch + gradient all-reduce), so
cross-pod (DCN) traffic is one all-reduce per step. Expert weights default to
FSDP×TP slicing of (E, d, f); ``ep=True`` switches them to expert parallelism
(E over 'model'), which removes the TP collectives from expert GEMMs — one of
the §Perf hillclimb levers.

Expert parallelism (EP) design
------------------------------

Under ``ep=True`` the expert dim of every MoE stack is sharded over the
``tp_axis`` ('model'). Two rules keep the ragged (dropless) forward exact:

1. **Routing is replicated.** Router logits and top-k indices are computed
   ONCE in GSPMD land from the (replicated-over-'model') activations, and
   enter the expert compute through a ``shard_map`` boundary whose in_specs
   do not mention the 'model' axis — i.e. every expert shard receives the
   IDENTICAL routing decisions for its expert slice. Letting GSPMD partition
   the routed dispatch itself is what the seed did: the XLA partitioner
   sharded ``group_sizes`` over 'model' and each shard misread its local
   slice as global cumulative row offsets (err ~5.0 vs the reference, the
   old ``test_ep_sharding_lowers`` xfail).
2. **Expert compute is shard-local.** Inside the ``shard_map``
   (``repro.models.moe._ep_ragged_forward``) each 'model' shard gathers the
   tokens routed to its local expert slice (non-owned tokens fall into a
   zero-weight sentinel group), runs the grouped GEMMs on its E/tp experts,
   and a single ``psum`` over 'model' combines the partial outputs. No
   expert weight is ever all-gathered — each device holds and reads
   ``expert_bytes / tp`` (see :func:`expert_param_bytes_per_device`).

Merged (HC-SMoE) stacks ride the same path: ``group_map`` routing happens in
the replicated stage, so expert shards agree on merged-slot ids too. When the
merged slot count does not divide the EP degree, pad with
:func:`pad_expert_slots` (zero-weight slots that routing can never reach).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParallelConfig:
    dp_axes: Tuple[str, ...] = ("data",)   # batch axes; ('pod','data') multipod
    fsdp_axis: Optional[str] = "data"      # param sharding axis (ZeRO-3 style)
    tp_axis: Optional[str] = "model"
    ep: bool = False                       # expert parallelism for MoE stacks
    seq_shard: bool = False                # sequence(activation) sharding (SP)
    remat: str = "full"
    moe_mode: str = "ragged"
    scan_unroll: bool = False              # dry-run: unroll block scan
    # ZeRO-3 semantics: weights stored FSDP-sharded but all-gathered at use
    # (with_sharding_constraint to the TP-only layout). Without this, GSPMD
    # may resolve the fsdp-sharded contracting dim by all-reducing full-batch
    # activations instead of all-gathering small weights — measured 32 GiB
    # per-step ARs on llama3.2-1b train_4k. Off for decode (tiny activations,
    # weights should stay put).
    weight_gather: bool = True

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _leaf_spec(path: Tuple[str, ...], ndim: int, pc: ParallelConfig,
               mesh_axis_sizes=None, num_kv_heads=None) -> P:
    """Spec for one (unstacked) param leaf, dispatched on its dict path."""
    f, t = pc.fsdp_axis, pc.tp_axis
    name = path[-1]
    sub = path[-2] if len(path) >= 2 else ""

    # ---- norms / scalars / small vectors: replicated
    if name.startswith("ln") or "norm" in name or name in (
            "b", "b_gates", "conv_b", "dt_proj_b", "D", "router_mask",
            "group_map", "r"):
        return P(*([None] * ndim))

    if name == "embed":
        # d replicated: the vocab-parallel lookup (masked gather + psum) and
        # the unembed contraction both want vocab-only sharding
        return P(t, None)
    if name == "lm_head":
        return P(f, t)

    # ---- attention
    if sub in ("mixer", "cross") or name in ("wq", "wk", "wv", "wo"):
        if name in ("wq", "wk", "wv"):
            return P(f, t)
        if name == "wo":
            return P(t, f)
    # ---- MLA
    if name in ("w_dq", "w_dkv", "w_kr"):
        return P(f, None)
    if name in ("w_uq", "w_uk", "w_uv"):
        return P(None, t)
    if name == "w_o":
        return P(t, f)
    # ---- dense FFN
    if name in ("wg", "wu") and ndim == 2:
        return P(f, t)
    if name == "wd" and ndim == 2:
        return P(t, f)
    # ---- MoE expert stacks (E, d, f) / (E, f, d)
    if name in ("wg", "wu") and ndim == 3:
        return P(t, f, None) if pc.ep else P(None, f, t)
    if name == "wd" and ndim == 3:
        return P(t, None, f) if pc.ep else P(None, t, f)
    if name == "router":
        return P(f, None)
    # ---- mamba
    if name == "in_proj":
        return P(f, t)
    if name == "conv_w":
        return P(None, t)
    if name == "x_proj":
        return P(t, None)
    if name == "dt_proj_w":
        return P(None, t)
    if name == "A_log":
        return P(t, None)
    if name == "out_proj":
        return P(t, f)
    # ---- xLSTM
    if name == "up":
        return P(f, t)
    if name == "down":
        return P(t, f)
    if name == "w_gates":
        return P(t, None)
    if name == "w":  # sLSTM input projection
        return P(f, None)

    return P(*([None] * ndim))


def param_pspecs(params_tree, pc: ParallelConfig):
    """PartitionSpec pytree matching ``params_tree`` (arrays OR
    ShapeDtypeStructs — only shapes are read)."""

    def visit(path, leaf):
        names = tuple(
            p.key for p in path if isinstance(p, jax.tree_util.DictKey))
        ndim = len(leaf.shape)
        stacked = "blocks" in names
        base_ndim = ndim - 1 if stacked else ndim
        spec = _leaf_spec(names, base_ndim, pc)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params_tree)


# ---------------------------------------------------------------------------
# Batch / activation / cache specs
# ---------------------------------------------------------------------------


def batch_pspecs(batch_tree, pc: ParallelConfig):
    dp = pc.dp

    def visit(path, leaf):
        ndim = len(leaf.shape)
        return P(dp, *([None] * (ndim - 1)))

    return jax.tree_util.tree_map_with_path(visit, batch_tree)


def cache_pspecs(cfg, cache_tree, pc: ParallelConfig,
                 ctx_shard: bool = False):
    """KV caches: batch over dp; kv-heads (or head_dim) over tp; recurrent
    state channel dims over tp.

    PAGED caches (detected by the top-level ``page_table`` key) differ:
    the leading dim of ``k``/``v`` pools and the shared ``kv_pos`` is the
    POOL PAGE index (a logical address space every shard must resolve
    identically), not batch — pools shard over heads/head_dim only, and
    ``kv_pos`` is replicated. ``pos``/``page_table`` keep batch over dp.

    ctx_shard=True (long-context decode where global_batch < dp size):
    replicate batch, shard the cache LENGTH dim over the dp axis instead —
    context parallelism; softmax over the sharded length lowers to local
    partials + a tiny psum."""
    dp, t = pc.dp, pc.tp_axis
    paged = isinstance(cache_tree, dict) and "page_table" in cache_tree

    def visit(path, leaf):
        names = tuple(
            p.key for p in path if isinstance(p, jax.tree_util.DictKey))
        name = names[-1]
        ndim = len(leaf.shape)
        stacked = "blocks" in names
        base = ndim - 1 if stacked else ndim
        b, l = (None, dp) if ctx_shard else (dp, None)
        if name == "pos":
            spec = P(b)
        elif paged and name in ("k", "v"):
            # (N, page, K, hd) shared pool: page address space replicated,
            # heads over tp (head_dim fallback via cache_pspecs_sized)
            spec = P(None, None, t, None)
        elif paged and name == "kv_pos":
            spec = P(None, None)  # (N, page): shared pool metadata
        elif name == "page_table":
            spec = P(b, None)  # (B, P): logical table, batch over dp
        elif name in ("k", "v", "ck", "cv"):
            # (B, W, K, hd): shard kv heads over tp (every assigned arch has
            # hd % 16 == 0, and K % tp when K >= tp); fall back to hd.
            spec = P(b, l, t, None)
        elif name in ("kv_pos", "c_len"):
            spec = P(b) if base == 1 else P(b, l)
        elif name in ("c_kv", "k_rope"):
            spec = P(b, l, None)
        elif name == "ssm":
            spec = P(b, t, None)
        elif name == "conv":
            spec = P(b, None, t)
        elif name == "C":
            spec = P(b, None, None, None)
        elif name in ("n", "m", "c", "h"):
            spec = P(b, *([None] * (base - 1)))
        else:
            spec = P(b, *([None] * (base - 1)))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(visit, cache_tree)


def choose_kv_spec(cfg, pc: ParallelConfig, tp_size: int):
    """Shard kv heads when they divide tp; else head_dim; else replicate.
    Mirrors repro.kernels.partition.kernel_sharding's strategy choice so
    cache placement and per-shard kernel launches agree."""
    if cfg.num_kv_heads % tp_size == 0:
        return P(pc.dp, None, pc.tp_axis, None)
    if cfg.head_dim % tp_size == 0:
        return P(pc.dp, None, None, pc.tp_axis)
    return P(pc.dp, None, None, None)


def kv_shard_degree(cfg, tp_size: int) -> int:
    """How many ways choose_kv_spec/kernel_sharding split each K/V array."""
    if cfg.num_kv_heads % tp_size == 0 or cfg.head_dim % tp_size == 0:
        return tp_size
    return 1


def cache_pspecs_sized(cfg, cache_tree, pc: ParallelConfig, tp_size: int,
                       ctx_shard: bool = False):
    """cache_pspecs with the kv-head/head-dim choice resolved for a mesh.
    Covers both the contiguous ring layout (batch-leading K/V) and the
    paged pool layout (page-leading K/V, replicated page dims)."""
    base = cache_pspecs(cfg, cache_tree, pc, ctx_shard=ctx_shard)
    if cfg.num_kv_heads % tp_size == 0:
        return base
    t = pc.tp_axis
    hd_t = t if cfg.head_dim % tp_size == 0 else None
    b, l = (None, pc.dp) if ctx_shard else (pc.dp, None)
    swaps = {}
    for head_spec, hd_spec in (
        (P(b, l, t, None), P(b, l, None, hd_t)),        # contiguous ring
        (P(None, None, t, None), P(None, None, None, hd_t)),  # paged pool
    ):
        swaps[head_spec] = hd_spec
        swaps[P(None, *head_spec)] = P(None, *hd_spec)  # stacked

    def fix(spec):
        return swaps.get(spec, spec)

    return jax.tree.map(fix, base,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# ZeRO-3 gather-at-use
# ---------------------------------------------------------------------------

import dataclasses as _dc

from jax import lax as _lax


def compute_pspecs_for_layer(layer_params, pc: ParallelConfig):
    """Per-leaf COMPUTE layout for one (unstacked) layer param subtree: the
    storage spec with the fsdp axis dropped (i.e. the Megatron-TP layout)."""
    pc_nofsdp = _dc.replace(pc, fsdp_axis=None)

    def visit(path, leaf):
        names = tuple(
            p.key for p in path if isinstance(p, jax.tree_util.DictKey))
        return _leaf_spec(names, len(leaf.shape), pc_nofsdp)

    return jax.tree_util.tree_map_with_path(visit, layer_params)


def get_context_mesh():
    """The ``with mesh:`` context Mesh, or None when no mesh is active."""
    try:  # deprecated-but-functional introspection of the `with mesh:` env
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            from jax.interpreters import pxla

            mesh = pxla.thread_resources.env.physical_mesh
            return None if mesh.empty else mesh
    except Exception:  # pragma: no cover
        return None


def _mesh_in_context() -> bool:
    return get_context_mesh() is not None


def _is_expert_stack(names) -> bool:
    """True for routed MoE expert stacks (E, d, f)/(E, f, d) — NOT the
    shared-expert dense FFN that also lives under the 'moe' subtree."""
    return ("moe" in names and "shared" not in names
            and names[-1] in ("wg", "wu", "wd"))


def pad_expert_slots(params, multiple: int):
    """Pad every MoE expert stack with zero-weight slots so the expert dim
    divides ``multiple`` (the EP shard count).

    Routing can never reach a padded slot (``group_map`` values index only
    the live slots), so outputs are bit-identical; each EP shard simply gets
    an even slice of the (padded) expert dim. Only the ragged/pallas EP path
    needs this — ``capacity`` mode derives its per-expert capacity from the
    slot count, so pad before choosing a capacity factor there.
    """
    import jax.numpy as jnp

    def visit(path, leaf):
        names = tuple(
            p.key for p in path if isinstance(p, jax.tree_util.DictKey))
        e_axis = 1 if "blocks" in names else 0  # stacked: (L, E, ...)
        if not _is_expert_stack(names) or leaf.ndim != e_axis + 3:
            return leaf
        pad = (-leaf.shape[e_axis]) % multiple
        if not pad:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[e_axis] = (0, pad)
        return jnp.pad(leaf, widths)

    return jax.tree_util.tree_map_with_path(visit, params)


def expert_param_bytes_per_device(params) -> dict:
    """Per-device byte footprint of the MoE expert stacks (wg/wu/wd).

    Reads the ACTUAL addressable shards, so an EP-sharded tree reports
    ``total / ep_degree`` per device while a replicated tree reports the
    full stack on every device — the number the serving benchmark uses to
    show merged-vs-unmerged memory savings per chip.

    Returns ``{"total": int, "per_device": {device_id: bytes},
    "max_per_device": int}``.
    """
    per_device: dict = {}
    total = 0

    def visit(path, leaf):
        nonlocal total
        names = tuple(
            p.key for p in path if isinstance(p, jax.tree_util.DictKey))
        if not _is_expert_stack(names):
            return leaf
        total += leaf.nbytes
        shards = getattr(leaf, "addressable_shards", None)
        if shards:
            for sh in shards:
                key = getattr(sh.device, "id", sh.device)
                per_device[key] = per_device.get(key, 0) + sh.data.nbytes
        else:  # plain numpy / single-device array
            per_device[0] = per_device.get(0, 0) + leaf.nbytes
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return {"total": total, "per_device": per_device,
            "max_per_device": max(per_device.values()) if per_device else 0}


def gather_layer_params(layer_params, pc: ParallelConfig):
    """Constrain every weight to its gathered (TP-only) layout at use. XLA
    emits (async) all-gathers over the fsdp axis — classic ZeRO-3. No-op
    when no mesh is in context (CPU tests / benchmarks)."""
    if pc is None or not pc.weight_gather or pc.fsdp_axis is None:
        return layer_params
    if not _mesh_in_context():
        return layer_params
    specs = compute_pspecs_for_layer(layer_params, pc)
    return jax.tree.map(
        lambda w, s: _lax.with_sharding_constraint(w, s), layer_params, specs)
